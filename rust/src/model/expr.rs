//! Model expression trees: parsing, evaluation, symbolic
//! differentiation (the calibration Jacobian of Section 7.2).

use std::collections::BTreeMap;
use std::fmt;

/// An arithmetic expression over parameters (`p_...`), features
/// (`f_...`), literals and `tanh`.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelExpr {
    Num(f64),
    Param(String),
    Feature(String),
    Add(Box<ModelExpr>, Box<ModelExpr>),
    Sub(Box<ModelExpr>, Box<ModelExpr>),
    Mul(Box<ModelExpr>, Box<ModelExpr>),
    Div(Box<ModelExpr>, Box<ModelExpr>),
    Tanh(Box<ModelExpr>),
}

use ModelExpr::*;

impl ModelExpr {
    pub fn num(x: f64) -> ModelExpr {
        Num(x)
    }

    pub fn param(name: &str) -> ModelExpr {
        Param(name.to_string())
    }

    pub fn feature(name: &str) -> ModelExpr {
        Feature(name.to_string())
    }

    pub fn add(a: ModelExpr, b: ModelExpr) -> ModelExpr {
        Add(Box::new(a), Box::new(b))
    }

    pub fn sub(a: ModelExpr, b: ModelExpr) -> ModelExpr {
        Sub(Box::new(a), Box::new(b))
    }

    pub fn mul(a: ModelExpr, b: ModelExpr) -> ModelExpr {
        Mul(Box::new(a), Box::new(b))
    }

    pub fn div(a: ModelExpr, b: ModelExpr) -> ModelExpr {
        Div(Box::new(a), Box::new(b))
    }

    pub fn tanh(a: ModelExpr) -> ModelExpr {
        Tanh(Box::new(a))
    }

    /// Parse from text. Identifier characters include `:{},<>.$` so
    /// feature ids with stride maps survive tokenization.
    pub fn parse(text: &str) -> Result<ModelExpr, String> {
        let tokens = tokenize(text)?;
        let mut p = Parser { tokens, pos: 0 };
        let e = p.expr()?;
        if p.pos != p.tokens.len() {
            return Err(format!("trailing tokens after expression: {:?}", &p.tokens[p.pos..]));
        }
        Ok(e)
    }

    /// Evaluate with parameter and feature environments.
    pub fn eval(
        &self,
        params: &BTreeMap<String, f64>,
        feats: &BTreeMap<String, f64>,
    ) -> Result<f64, String> {
        Ok(match self {
            Num(x) => *x,
            Param(p) => *params
                .get(p)
                .ok_or_else(|| format!("unbound parameter '{p}'"))?,
            Feature(f) => *feats
                .get(f)
                .ok_or_else(|| format!("unbound feature '{f}'"))?,
            Add(a, b) => a.eval(params, feats)? + b.eval(params, feats)?,
            Sub(a, b) => a.eval(params, feats)? - b.eval(params, feats)?,
            Mul(a, b) => a.eval(params, feats)? * b.eval(params, feats)?,
            Div(a, b) => a.eval(params, feats)? / b.eval(params, feats)?,
            Tanh(a) => a.eval(params, feats)?.tanh(),
        })
    }

    /// Symbolic partial derivative w.r.t. parameter `p` (used for the
    /// calibration Jacobian; models must be differentiable, §6).
    pub fn diff(&self, p: &str) -> ModelExpr {
        match self {
            Num(_) | Feature(_) => Num(0.0),
            Param(q) => Num(if q == p { 1.0 } else { 0.0 }),
            Add(a, b) => ModelExpr::add(a.diff(p), b.diff(p)).simplified(),
            Sub(a, b) => ModelExpr::sub(a.diff(p), b.diff(p)).simplified(),
            Mul(a, b) => ModelExpr::add(
                ModelExpr::mul(a.diff(p), (**b).clone()),
                ModelExpr::mul((**a).clone(), b.diff(p)),
            )
            .simplified(),
            Div(a, b) => ModelExpr::div(
                ModelExpr::sub(
                    ModelExpr::mul(a.diff(p), (**b).clone()),
                    ModelExpr::mul((**a).clone(), b.diff(p)),
                ),
                ModelExpr::mul((**b).clone(), (**b).clone()),
            )
            .simplified(),
            // d tanh(u) = (1 - tanh(u)^2) u'
            Tanh(a) => {
                let t = ModelExpr::tanh((**a).clone());
                ModelExpr::mul(
                    ModelExpr::sub(Num(1.0), ModelExpr::mul(t.clone(), t)),
                    a.diff(p),
                )
                .simplified()
            }
        }
    }

    /// Constant-fold trivial algebra (0 + x, 1 * x, 0 * x, ...).
    pub fn simplified(&self) -> ModelExpr {
        match self {
            Add(a, b) => {
                let (a, b) = (a.simplified(), b.simplified());
                match (&a, &b) {
                    (Num(x), Num(y)) => Num(x + y),
                    (Num(z), _) if *z == 0.0 => b,
                    (_, Num(z)) if *z == 0.0 => a,
                    _ => ModelExpr::add(a, b),
                }
            }
            Sub(a, b) => {
                let (a, b) = (a.simplified(), b.simplified());
                match (&a, &b) {
                    (Num(x), Num(y)) => Num(x - y),
                    (_, Num(z)) if *z == 0.0 => a,
                    _ => ModelExpr::sub(a, b),
                }
            }
            Mul(a, b) => {
                let (a, b) = (a.simplified(), b.simplified());
                match (&a, &b) {
                    (Num(x), Num(y)) => Num(x * y),
                    (Num(z), _) | (_, Num(z)) if *z == 0.0 => Num(0.0),
                    (Num(o), _) if *o == 1.0 => b,
                    (_, Num(o)) if *o == 1.0 => a,
                    _ => ModelExpr::mul(a, b),
                }
            }
            Div(a, b) => {
                let (a, b) = (a.simplified(), b.simplified());
                match (&a, &b) {
                    (Num(z), _) if *z == 0.0 => Num(0.0),
                    (_, Num(o)) if *o == 1.0 => a,
                    _ => ModelExpr::div(a, b),
                }
            }
            Tanh(a) => ModelExpr::tanh(a.simplified()),
            other => other.clone(),
        }
    }

    fn collect(&self, params: &mut Vec<String>, feats: &mut Vec<String>) {
        match self {
            Param(p) => {
                if !params.contains(p) {
                    params.push(p.clone());
                }
            }
            Feature(f) => {
                if !feats.contains(f) {
                    feats.push(f.clone());
                }
            }
            Add(a, b) | Sub(a, b) | Mul(a, b) | Div(a, b) => {
                a.collect(params, feats);
                b.collect(params, feats);
            }
            Tanh(a) => a.collect(params, feats),
            Num(_) => {}
        }
    }

    /// Parameter names in first-occurrence order.
    pub fn params(&self) -> Vec<String> {
        let mut p = Vec::new();
        let mut f = Vec::new();
        self.collect(&mut p, &mut f);
        p
    }

    /// Feature identifiers in first-occurrence order.
    pub fn features(&self) -> Vec<String> {
        let mut p = Vec::new();
        let mut f = Vec::new();
        self.collect(&mut p, &mut f);
        f
    }
}

impl fmt::Display for ModelExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Num(x) => write!(f, "{x}"),
            Param(p) => write!(f, "{p}"),
            Feature(x) => write!(f, "{x}"),
            Add(a, b) => write!(f, "({a} + {b})"),
            Sub(a, b) => write!(f, "({a} - {b})"),
            Mul(a, b) => write!(f, "({a} * {b})"),
            Div(a, b) => write!(f, "({a} / {b})"),
            Tanh(a) => write!(f, "tanh({a})"),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Number(f64),
    Plus,
    Minus,
    Star,
    Slash,
    LParen,
    RParen,
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || matches!(c, '_' | ':' | '{' | '}' | ',' | '<' | '>' | '.' | '$')
}

fn tokenize(text: &str) -> Result<Vec<Tok>, String> {
    let mut out = Vec::new();
    let mut chars = text.chars().peekable();
    while let Some(&c) = chars.peek() {
        match c {
            c if c.is_whitespace() => {
                chars.next();
            }
            '+' => {
                chars.next();
                out.push(Tok::Plus);
            }
            '-' => {
                chars.next();
                out.push(Tok::Minus);
            }
            '*' => {
                chars.next();
                out.push(Tok::Star);
            }
            '/' => {
                chars.next();
                out.push(Tok::Slash);
            }
            '(' => {
                chars.next();
                out.push(Tok::LParen);
            }
            ')' => {
                chars.next();
                out.push(Tok::RParen);
            }
            c if c.is_ascii_digit() => {
                let mut s = String::new();
                while let Some(&c) = chars.peek() {
                    if c.is_ascii_digit() || c == '.' || c == 'e' || c == 'E' {
                        s.push(c);
                        chars.next();
                        // allow e-5 / e+5 exponents
                        if (s.ends_with('e') || s.ends_with('E'))
                            && matches!(chars.peek(), Some('-') | Some('+'))
                        {
                            s.push(chars.next().unwrap());
                        }
                    } else {
                        break;
                    }
                }
                out.push(Tok::Number(
                    s.parse().map_err(|_| format!("bad number '{s}'"))?,
                ));
            }
            c if c.is_alphabetic() || c == '_' => {
                let mut s = String::new();
                while let Some(&c) = chars.peek() {
                    if is_ident_char(c) {
                        s.push(c);
                        chars.next();
                    } else {
                        break;
                    }
                }
                out.push(Tok::Ident(s));
            }
            other => return Err(format!("unexpected character '{other}'")),
        }
    }
    Ok(out)
}

struct Parser {
    tokens: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.tokens.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.tokens.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expr(&mut self) -> Result<ModelExpr, String> {
        let mut lhs = self.term()?;
        while let Some(t) = self.peek() {
            match t {
                Tok::Plus => {
                    self.next();
                    lhs = ModelExpr::add(lhs, self.term()?);
                }
                Tok::Minus => {
                    self.next();
                    lhs = ModelExpr::sub(lhs, self.term()?);
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    fn term(&mut self) -> Result<ModelExpr, String> {
        let mut lhs = self.factor()?;
        while let Some(t) = self.peek() {
            match t {
                Tok::Star => {
                    self.next();
                    lhs = ModelExpr::mul(lhs, self.factor()?);
                }
                Tok::Slash => {
                    self.next();
                    lhs = ModelExpr::div(lhs, self.factor()?);
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    fn factor(&mut self) -> Result<ModelExpr, String> {
        match self.next() {
            Some(Tok::Number(x)) => Ok(Num(x)),
            Some(Tok::Minus) => Ok(ModelExpr::sub(Num(0.0), self.factor()?)),
            Some(Tok::LParen) => {
                let e = self.expr()?;
                match self.next() {
                    Some(Tok::RParen) => Ok(e),
                    other => Err(format!("expected ')', got {other:?}")),
                }
            }
            Some(Tok::Ident(name)) => {
                if name == "tanh" {
                    match self.next() {
                        Some(Tok::LParen) => {
                            let e = self.expr()?;
                            match self.next() {
                                Some(Tok::RParen) => Ok(ModelExpr::tanh(e)),
                                other => Err(format!("expected ')', got {other:?}")),
                            }
                        }
                        other => Err(format!("expected '(' after tanh, got {other:?}")),
                    }
                } else if name.starts_with("p_") {
                    Ok(Param(name))
                } else if name.starts_with("f_") {
                    Ok(Feature(name))
                } else {
                    Err(format!(
                        "identifier '{name}' must start with p_ or f_ (or be tanh)"
                    ))
                }
            }
            other => Err(format!("unexpected token {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn envs() -> (BTreeMap<String, f64>, BTreeMap<String, f64>) {
        let params = [("p_a".to_string(), 2.0), ("p_b".to_string(), 3.0)]
            .into_iter()
            .collect();
        let feats = [("f_op_float32_madd".to_string(), 5.0)]
            .into_iter()
            .collect();
        (params, feats)
    }

    #[test]
    fn parse_and_eval_basic() {
        let (p, f) = envs();
        let e = ModelExpr::parse("p_a * f_op_float32_madd + p_b").unwrap();
        assert_eq!(e.eval(&p, &f).unwrap(), 13.0);
    }

    #[test]
    fn precedence_and_parens() {
        let (p, f) = envs();
        let e = ModelExpr::parse("(p_a + p_b) * 2").unwrap();
        assert_eq!(e.eval(&p, &f).unwrap(), 10.0);
        let e = ModelExpr::parse("p_a + p_b * 2").unwrap();
        assert_eq!(e.eval(&p, &f).unwrap(), 8.0);
        let e = ModelExpr::parse("-p_a + 4").unwrap();
        assert_eq!(e.eval(&p, &f).unwrap(), 2.0);
    }

    #[test]
    fn tanh_eval_and_diff() {
        let (mut p, f) = envs();
        p.insert("p_edge".into(), 10.0);
        let e = ModelExpr::parse("(tanh(p_edge * (p_a - p_b)) + 1) / 2").unwrap();
        let v = e.eval(&p, &f).unwrap();
        assert!((v - ((10.0f64 * -1.0).tanh() + 1.0) / 2.0).abs() < 1e-15);

        // d/dp_a = edge * sech^2(edge*(a-b)) / 2
        let d = e.diff("p_a");
        let got = d.eval(&p, &f).unwrap();
        let u: f64 = 10.0 * (2.0 - 3.0);
        let expected = 10.0 * (1.0 - u.tanh().powi(2)) / 2.0;
        assert!((got - expected).abs() < 1e-12, "{got} vs {expected}");
    }

    #[test]
    fn feature_ids_with_braces_tokenize() {
        let e = ModelExpr::parse(
            "p_x * f_mem_access_global_float32_lstrides:{0:1,1:>16}_afr:1",
        )
        .unwrap();
        assert_eq!(
            e.features(),
            vec!["f_mem_access_global_float32_lstrides:{0:1,1:>16}_afr:1"]
        );
    }

    #[test]
    fn diff_of_linear_model_is_feature() {
        let e = ModelExpr::parse("p_a * f_op_float32_madd + p_b * f_op_float32_madd")
            .unwrap();
        let d = e.diff("p_a").simplified();
        assert_eq!(d, Feature("f_op_float32_madd".into()));
    }

    #[test]
    fn prop_diff_matches_finite_difference() {
        prop::check("symbolic diff vs finite difference", 40, |rng| {
            // Random small expression over p_a, p_b, f_x.
            fn gen(rng: &mut crate::util::Rng, depth: u32) -> ModelExpr {
                if depth == 0 {
                    match rng.below(4) {
                        0 => Num(rng.uniform_in(0.5, 2.0)),
                        1 => Param("p_a".into()),
                        2 => Param("p_b".into()),
                        _ => Feature("f_x".into()),
                    }
                } else {
                    match rng.below(5) {
                        0 => ModelExpr::add(gen(rng, depth - 1), gen(rng, depth - 1)),
                        1 => ModelExpr::sub(gen(rng, depth - 1), gen(rng, depth - 1)),
                        2 => ModelExpr::mul(gen(rng, depth - 1), gen(rng, depth - 1)),
                        3 => ModelExpr::tanh(gen(rng, depth - 1)),
                        _ => gen(rng, 0),
                    }
                }
            }
            let e = gen(rng, 3);
            let a = rng.uniform_in(0.5, 1.5);
            let b = rng.uniform_in(0.5, 1.5);
            let fx = rng.uniform_in(0.5, 1.5);
            let mk = |a: f64| -> BTreeMap<String, f64> {
                [("p_a".to_string(), a), ("p_b".to_string(), b)]
                    .into_iter()
                    .collect()
            };
            let feats: BTreeMap<String, f64> =
                [("f_x".to_string(), fx)].into_iter().collect();
            let h = 1e-6;
            let fd = (e.eval(&mk(a + h), &feats).unwrap()
                - e.eval(&mk(a - h), &feats).unwrap())
                / (2.0 * h);
            let sym = e.diff("p_a").eval(&mk(a), &feats).unwrap();
            prop::ensure_close(sym, fd, 1e-4, &format!("d/dp_a of {e}"))
        });
    }

    #[test]
    fn parse_errors() {
        assert!(ModelExpr::parse("p_a +").is_err());
        assert!(ModelExpr::parse("q_bogus").is_err());
        assert!(ModelExpr::parse("tanh p_a").is_err());
        assert!(ModelExpr::parse("(p_a").is_err());
    }

    #[test]
    fn scientific_notation() {
        let e = ModelExpr::parse("1.5e-9 * p_a").unwrap();
        let (p, f) = envs();
        assert!((e.eval(&p, &f).unwrap() - 3e-9).abs() < 1e-24);
    }
}
