//! The paper's three-cost-component model family (Section 8.1).
//!
//! Costs are grouped into overhead (barriers, launches), global memory
//! and on-chip work; the *linear* model (Eq. 7) sums them, the
//! *nonlinear* model (Eq. 8) lets on-chip cost hide behind global
//! memory traffic through the differentiable step switch (Eq. 5/6).
//!
//! A [`CostModel`] expands to a general [`ModelExpr`] for the native
//! evaluator, and maps directly onto the AOT JAX/Pallas `lm_step`
//! artifact (feature columns + group masks + mode scalar) for the
//! accelerated calibration path — both paths are cross-checked in
//! tests and benchmarked as an ablation.

use super::expr::ModelExpr;
use super::Model;
use crate::features::FeatureSpec;

/// Cost component a feature belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostGroup {
    Overhead = 0,
    Gmem = 1,
    OnChip = 2,
}

/// One `parameter * feature` cost term.
#[derive(Clone, Debug, PartialEq)]
pub struct CostTerm {
    pub param: String,
    pub feature: String,
    pub group: CostGroup,
}

/// A model in the builtin family.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// Device name for the output feature (`f_cl_wall_time_<device>`).
    pub device: String,
    pub terms: Vec<CostTerm>,
    /// Eq. 8 (overlap) when true, Eq. 7 (linear) when false.
    pub nonlinear: bool,
}

/// The parameter name of the step-sharpness parameter (Eq. 6).
pub const EDGE_PARAM: &str = "p_edge";

impl CostModel {
    pub fn new(device: &str, nonlinear: bool) -> CostModel {
        CostModel {
            device: device.to_string(),
            terms: Vec::new(),
            nonlinear,
        }
    }

    /// Add a term; the parameter name is derived from `param`
    /// (prefixed `p_` if missing).
    pub fn term(mut self, param: &str, feature: &str, group: CostGroup) -> CostModel {
        let param = if param.starts_with("p_") {
            param.to_string()
        } else {
            format!("p_{param}")
        };
        self.terms.push(CostTerm {
            param,
            feature: feature.to_string(),
            group,
        });
        self
    }

    pub fn output_feature(&self) -> String {
        format!("f_cl_wall_time_{}", self.device)
    }

    /// Ordered feature identifiers (the AOT artifact's column order).
    pub fn feature_columns(&self) -> Vec<String> {
        self.terms.iter().map(|t| t.feature.clone()).collect()
    }

    /// Ordered parameter names; for nonlinear models the trailing
    /// parameter is [`EDGE_PARAM`] (matching the artifact's `p[J]`).
    pub fn param_names(&self) -> Vec<String> {
        let mut out: Vec<String> = self.terms.iter().map(|t| t.param.clone()).collect();
        out.push(EDGE_PARAM.to_string());
        out
    }

    /// Group mask matrix (3 x J), the artifact's `groups` argument.
    pub fn groups_matrix(&self) -> [Vec<f64>; 3] {
        let j = self.terms.len();
        let mut g = [vec![0.0; j], vec![0.0; j], vec![0.0; j]];
        for (col, t) in self.terms.iter().enumerate() {
            g[t.group as usize][col] = 1.0;
        }
        g
    }

    /// The artifact's `mode` scalar.
    pub fn mode(&self) -> f64 {
        if self.nonlinear {
            1.0
        } else {
            0.0
        }
    }

    /// Group sub-expression `Σ p_i * f_i` over the given group.
    fn group_expr(&self, group: CostGroup) -> ModelExpr {
        let mut acc = ModelExpr::num(0.0);
        for t in self.terms.iter().filter(|t| t.group == group) {
            acc = ModelExpr::add(
                acc,
                ModelExpr::mul(
                    ModelExpr::param(&t.param),
                    ModelExpr::feature(&t.feature),
                ),
            );
        }
        acc.simplified()
    }

    /// Expand to a general Perflex model (the native-evaluator path).
    ///
    /// Nonlinear form matches the L1 kernel algebraically, using the
    /// scale-invariant switch (a variation of the paper's Eq. 6, which
    /// it explicitly admits): with u = a - b,
    /// `o + b + u * (tanh(p_edge * u / (a + b + eps)) + 1) / 2`.
    /// Scale invariance keeps calibration on output-scaled features
    /// consistent with prediction on raw feature values.
    pub fn to_model(&self) -> Model {
        let o = self.group_expr(CostGroup::Overhead);
        let a = self.group_expr(CostGroup::Gmem);
        let b = self.group_expr(CostGroup::OnChip);
        let expr = if self.nonlinear {
            let u = ModelExpr::sub(a.clone(), b.clone());
            let denom = ModelExpr::add(
                ModelExpr::add(a, b.clone()),
                ModelExpr::num(1e-30),
            );
            let s1 = ModelExpr::div(
                ModelExpr::add(
                    ModelExpr::tanh(ModelExpr::div(
                        ModelExpr::mul(ModelExpr::param(EDGE_PARAM), u.clone()),
                        denom,
                    )),
                    ModelExpr::num(1.0),
                ),
                ModelExpr::num(2.0),
            );
            ModelExpr::add(ModelExpr::add(o, b), ModelExpr::mul(u, s1)).simplified()
        } else {
            ModelExpr::add(ModelExpr::add(o, a), b).simplified()
        };
        Model {
            output: FeatureSpec::parse(&self.output_feature()).expect("valid output"),
            expr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn example(nonlinear: bool) -> CostModel {
        CostModel::new("titan_v", nonlinear)
            .term("launch", "f_sync_kernel_launch", CostGroup::Overhead)
            .term("gmem_a", "f_mem_access_tag:aLD", CostGroup::Gmem)
            .term("gmem_b", "f_mem_access_tag:bLD", CostGroup::Gmem)
            .term("f32madd", "f_op_float32_madd", CostGroup::OnChip)
            .term("f32l", "f_mem_access_local_float32", CostGroup::OnChip)
    }

    fn envs(
        feats: &[(&str, f64)],
        params: &[(&str, f64)],
    ) -> (BTreeMap<String, f64>, BTreeMap<String, f64>) {
        (
            params
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
            feats.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        )
    }

    #[test]
    fn linear_model_sums_components() {
        let m = example(false).to_model();
        let (p, f) = envs(
            &[
                ("f_sync_kernel_launch", 1.0),
                ("f_mem_access_tag:aLD", 10.0),
                ("f_mem_access_tag:bLD", 20.0),
                ("f_op_float32_madd", 100.0),
                ("f_mem_access_local_float32", 50.0),
            ],
            &[
                ("p_launch", 1.0),
                ("p_gmem_a", 0.1),
                ("p_gmem_b", 0.2),
                ("p_f32madd", 0.01),
                ("p_f32l", 0.02),
            ],
        );
        // 1 + (1 + 4) + (1 + 1) = overhead 1, gmem 5, onchip 2.
        assert!((m.expr.eval(&p, &f).unwrap() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn nonlinear_model_hides_smaller_component() {
        let m = example(true).to_model();
        let (mut p, f) = envs(
            &[
                ("f_sync_kernel_launch", 1.0),
                ("f_mem_access_tag:aLD", 10.0),
                ("f_mem_access_tag:bLD", 20.0),
                ("f_op_float32_madd", 100.0),
                ("f_mem_access_local_float32", 50.0),
            ],
            &[
                ("p_launch", 1.0),
                ("p_gmem_a", 0.1),
                ("p_gmem_b", 0.2),
                ("p_f32madd", 0.01),
                ("p_f32l", 0.02),
            ],
        );
        p.insert("p_edge".into(), 1e4.to_owned());
        // gmem = 5, onchip = 2 -> total ≈ 1 + max(5, 2) = 6.
        let v = m.expr.eval(&p, &f).unwrap();
        assert!((v - 6.0).abs() < 1e-9, "{v}");
    }

    #[test]
    fn groups_matrix_matches_terms() {
        let cm = example(true);
        let g = cm.groups_matrix();
        assert_eq!(g[0], vec![1.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(g[1], vec![0.0, 1.0, 1.0, 0.0, 0.0]);
        assert_eq!(g[2], vec![0.0, 0.0, 0.0, 1.0, 1.0]);
        assert_eq!(cm.mode(), 1.0);
        assert_eq!(example(false).mode(), 0.0);
        assert_eq!(cm.param_names().last().unwrap(), EDGE_PARAM);
    }

    #[test]
    fn model_params_include_edge_only_when_nonlinear() {
        let lin = example(false).to_model();
        assert!(!lin.params().contains(&EDGE_PARAM.to_string()));
        let nl = example(true).to_model();
        assert!(nl.params().contains(&EDGE_PARAM.to_string()));
    }
}
