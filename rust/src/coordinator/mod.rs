//! Experiment coordinator: reproduces every table and figure of the
//! paper's evaluation (Section 8) on the simulated fleet.
//!
//! * [`expsets`] — the three evaluation models and their measurement-
//!   kernel sets (the content of Fig. 6).
//! * [`experiments`] — one harness per table/figure; each produces an
//!   [`report::ExperimentReport`] with both human-readable text and a
//!   JSON document written under `reports/`.
//! * [`report`] — rendering and error-statistics helpers.

pub mod experiments;
pub mod expsets;
pub mod report;

pub use experiments::{run_experiment, run_experiment_in_session, EXPERIMENT_IDS};
pub use report::ExperimentReport;
