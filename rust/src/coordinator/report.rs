//! Report rendering and error statistics.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Geometric mean of relative errors (the paper reports errors this
/// way, citing Fleming & Wallace 1986).  Zero errors are clamped.
pub fn geomean(errors: &[f64]) -> f64 {
    if errors.is_empty() {
        return 0.0;
    }
    let s: f64 = errors.iter().map(|e| e.max(1e-9).ln()).sum();
    (s / errors.len() as f64).exp()
}

/// Relative error |predicted - measured| / measured.
pub fn rel_err(predicted: f64, measured: f64) -> f64 {
    (predicted - measured).abs() / measured.abs().max(1e-300)
}

/// One prediction record.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub device: String,
    pub variant: String,
    pub sizes: BTreeMap<String, i64>,
    pub measured: f64,
    pub predicted: f64,
    /// The response variable `measured`/`predicted` are values of —
    /// [`Target::name`](crate::calibrate::Target::name) ("time",
    /// "energy", "avg_power").  Time predictions serialize exactly as
    /// before the target dimension existed (no `target` key), keeping
    /// pre-existing report JSON byte-identical.
    pub target: String,
}

impl Prediction {
    pub fn rel_err(&self) -> f64 {
        rel_err(self.predicted, self.measured)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("device", Json::from(self.device.as_str())),
            ("variant", self.variant.as_str().into()),
            (
                "sizes",
                Json::Obj(
                    self.sizes
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::from(*v)))
                        .collect(),
                ),
            ),
            ("measured_s", self.measured.into()),
            ("predicted_s", self.predicted.into()),
            ("rel_err", self.rel_err().into()),
        ];
        if self.target != "time" {
            fields.push(("target", self.target.as_str().into()));
        }
        Json::obj(fields)
    }
}

/// A finished experiment.
#[derive(Clone, Debug)]
pub struct ExperimentReport {
    pub id: String,
    pub title: String,
    pub lines: Vec<String>,
    pub predictions: Vec<Prediction>,
    pub summary: BTreeMap<String, f64>,
}

impl ExperimentReport {
    pub fn new(id: &str, title: &str) -> ExperimentReport {
        ExperimentReport {
            id: id.to_string(),
            title: title.to_string(),
            lines: Vec::new(),
            predictions: Vec::new(),
            summary: BTreeMap::new(),
        }
    }

    pub fn line(&mut self, s: impl Into<String>) {
        self.lines.push(s.into());
    }

    /// Geomean relative error over all predictions.
    pub fn overall_geomean(&self) -> f64 {
        geomean(
            &self
                .predictions
                .iter()
                .map(Prediction::rel_err)
                .collect::<Vec<_>>(),
        )
    }

    /// Geomean over predictions matching (device, variant) filters.
    pub fn geomean_where(
        &self,
        device: Option<&str>,
        variant: Option<&str>,
    ) -> f64 {
        geomean(
            &self
                .predictions
                .iter()
                .filter(|p| device.is_none_or(|d| p.device == d))
                .filter(|p| variant.is_none_or(|v| p.variant == v))
                .map(Prediction::rel_err)
                .collect::<Vec<_>>(),
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", self.id.as_str().into()),
            ("title", self.title.as_str().into()),
            (
                "lines",
                Json::Arr(self.lines.iter().map(|l| l.as_str().into()).collect()),
            ),
            (
                "predictions",
                Json::Arr(self.predictions.iter().map(Prediction::to_json).collect()),
            ),
            (
                "summary",
                Json::Obj(
                    self.summary
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::from(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Render to text.
    pub fn render(&self) -> String {
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        if !self.summary.is_empty() {
            out.push_str("-- summary --\n");
            for (k, v) in &self.summary {
                out.push_str(&format!("{k}: {v:.6}\n"));
            }
        }
        out
    }

    /// Write `reports/<id>.json`.
    pub fn write_json(&self, dir: &std::path::Path) -> Result<(), String> {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        let path = dir.join(format!("{}.json", self.id));
        std::fs::write(&path, self.to_json().to_string()).map_err(|e| e.to_string())
    }
}

/// Pretty-print seconds.
pub fn fmt_time(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.3} s")
    } else if t >= 1e-3 {
        format!("{:.3} ms", t * 1e3)
    } else {
        format!("{:.1} us", t * 1e6)
    }
}

/// Pretty-print joules.
pub fn fmt_energy(e: f64) -> String {
    if e >= 1.0 {
        format!("{e:.3} J")
    } else if e >= 1e-3 {
        format!("{:.3} mJ", e * 1e3)
    } else {
        format!("{:.1} uJ", e * 1e6)
    }
}

/// Pretty-print watts.
pub fn fmt_power(p: f64) -> String {
    if p >= 1.0 {
        format!("{p:.1} W")
    } else {
        format!("{:.1} mW", p * 1e3)
    }
}

/// Pretty-print a value of an arbitrary calibration target in its
/// natural unit.  Delegates to [`fmt_time`] for the time target, so
/// time-only output stays byte-identical to the pre-target renderer.
pub fn fmt_target(target: crate::calibrate::Target, v: f64) -> String {
    use crate::calibrate::Target;
    match target {
        Target::Time => fmt_time(v),
        Target::Energy => fmt_energy(v),
        Target::AvgPower => fmt_power(v),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_matches_hand_calculation() {
        let g = geomean(&[0.01, 0.04]);
        assert!((g - 0.02).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn report_roundtrips_json() {
        let mut r = ExperimentReport::new("figX", "test");
        r.line("hello");
        r.predictions.push(Prediction {
            device: "titan_v".into(),
            variant: "pf".into(),
            sizes: [("n".to_string(), 2048i64)].into_iter().collect(),
            measured: 1e-3,
            predicted: 1.1e-3,
            target: "time".into(),
        });
        r.summary.insert("geomean".into(), r.overall_geomean());
        let j = r.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("id").and_then(Json::as_str),
            Some("figX")
        );
        assert!((r.overall_geomean() - 0.1).abs() < 1e-9);
        // Time predictions keep the exact pre-target JSON shape...
        assert!(!j.contains("\"target\""), "{j}");
        // ...while other targets name themselves.
        r.predictions[0].target = "energy".into();
        let j2 = r.to_json().to_string();
        assert!(j2.contains("\"target\":\"energy\""), "{j2}");
    }

    #[test]
    fn target_formatters_pick_natural_units() {
        use crate::calibrate::Target;
        assert_eq!(fmt_target(Target::Time, 2.5e-3), fmt_time(2.5e-3));
        assert_eq!(fmt_energy(0.004), "4.000 mJ");
        assert_eq!(fmt_energy(2.0), "2.000 J");
        assert_eq!(fmt_energy(5e-5), "50.0 uJ");
        assert_eq!(fmt_power(212.5), "212.5 W");
        assert_eq!(fmt_power(0.25), "250.0 mW");
        assert_eq!(fmt_target(Target::AvgPower, 30.0), "30.0 W");
    }
}
