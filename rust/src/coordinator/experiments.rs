//! One harness per paper table/figure (Section 8 and the §2/§7.4
//! demonstrations).
//!
//! Every `run_experiment` invocation runs inside one
//! [`Session`](crate::session::Session) — the shared pipeline engine —
//! whose [`StatsCache`](crate::stats::StatsCache) is threaded through
//! measurement, feature gathering and prediction, so each distinct
//! (kernel, sub-group size) is symbolically counted exactly once per
//! run; with a `--store`-backed session, repeat runs load those counts
//! from disk and skip the pass entirely.  The per-device fleet loops of
//! the multi-device experiments are embarrassingly parallel and run on
//! scoped threads sharing that session; results are merged in fleet
//! order, so the reports are byte-identical to a sequential pass (and
//! to a warm re-run).  Model fits stay on the dispatching thread: the
//! optional AOT artifact wraps a PJRT client that is not assumed
//! thread-safe, and the fits are cheap next to the symbolic and
//! measurement work anyway.  With a store-backed session the
//! per-device *fleet fits* are artifacts too (keyed like the CLI's
//! `calibrate` fits, see [`crate::session::fit_key`]): a warm fleet
//! run loads every fit from disk, skips the per-device measurement
//! gathering wholesale, and still renders byte-identical reports.
//! The warm-start probes (`stored_fit`/`has_stored_fits`, issued once
//! per device × form before any gathering) are answered by the
//! store's journaled index: a warm fleet's "is this device already
//! calibrated?" sweep is hash-map hits plus payload decodes, with no
//! per-artifact validation parsing (a cold probe still pays one cheap
//! file-open miss — the index accelerates, it is not the authority).

use std::collections::BTreeMap;

use super::expsets;
use super::report::{fmt_target, fmt_time, geomean, ExperimentReport, Prediction};
use crate::calibrate::{
    eval_with_kernel_cached, gather_features_by_ids_cached, FeatureData, FitResult,
    LmOptions, Target,
};
use crate::features::FeatureSpec;
use crate::gpusim::{fleet, measure_with_cache, DeviceProfile};
use crate::ir::{FrozenKernel, KernelRef};
use crate::model::{CostGroup, CostModel};
use crate::runtime::{
    artifacts_available, fit_cost_model_aot, fit_cost_model_native, Artifacts,
};
use crate::session::{fit_key_parts, FitKey, Session};
use crate::stats;
use crate::uipick::apps::{build_dg, build_fdiff, build_matmul, DgVariant};
use crate::uipick::KernelCollection;

/// Every runnable experiment.
pub const EXPERIMENT_IDS: &[&str] = &[
    "fig1", "fig2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table1",
    "table2", "table3", "table4", "access", "all",
];

/// Dispatch with a fresh in-memory session.
pub fn run_experiment(id: &str, use_aot: bool) -> Result<ExperimentReport, String> {
    run_experiment_in_session(id, use_aot, &Session::new())
}

/// Dispatch inside a caller-provided session (the CLI passes a
/// `--store`-backed one so experiments warm-start across invocations).
pub fn run_experiment_in_session(
    id: &str,
    use_aot: bool,
    session: &Session,
) -> Result<ExperimentReport, String> {
    let aot = if use_aot && artifacts_available() {
        Some(Artifacts::load()?)
    } else {
        None
    };
    dispatch_experiment(id, aot.as_ref(), session)
}

fn dispatch_experiment(
    id: &str,
    aot: Option<&Artifacts>,
    session: &Session,
) -> Result<ExperimentReport, String> {
    match id {
        "fig1" => fig1_fig2(false, session),
        "fig2" => fig1_fig2(true, session),
        "fig4" => fig4(),
        "fig5" => fig5(aot, session),
        "fig6" => fig6(),
        "fig7" => fig7(aot, session),
        "fig8" => fig8(aot, session),
        "fig9" => fig9(aot, session),
        "table1" => table1(session),
        "table2" => table2(),
        "table3" => table3(aot, session),
        "table4" => table4(aot, session),
        // Not part of "all": the OVERALL number reproduces the paper's
        // fixed three-model evaluation.
        "access" => access_experiment(aot, session),
        "all" => all_experiments(aot, session),
        other => Err(format!(
            "unknown experiment '{other}'; known: {EXPERIMENT_IDS:?}"
        )),
    }
}

/// Fan `f` out over scoped threads, one per item, preserving item order
/// in the results — merged report fragments come back deterministic, so
/// parallel fleet runs render byte-identical to sequential ones.
fn parallel_map<I, T, F>(items: &[I], f: F) -> Result<Vec<T>, String>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> Result<T, String> + Sync,
{
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .iter()
            .map(|item| s.spawn(move || f(item)))
            .collect();
        // Join every handle before reporting: short-circuiting on the
        // first error would leave a possibly-panicked worker unjoined,
        // and `thread::scope` aborts on unhandled worker panics.  Keep
        // the panic payload — it carries the diagnostic (e.g. a Rat
        // overflow message naming the offending arithmetic).
        let joined: Vec<Result<T, String>> = handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(res) => res,
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<String>()
                        .map(String::as_str)
                        .or_else(|| payload.downcast_ref::<&str>().copied())
                        .unwrap_or("opaque panic payload");
                    Err(format!("fleet worker thread panicked: {msg}"))
                }
            })
            .collect();
        joined.into_iter().collect()
    })
}

fn predict<K: KernelRef>(
    cm: &CostModel,
    fit: &FitResult,
    kernel: &K,
    env: &BTreeMap<String, i64>,
    device: &DeviceProfile,
    session: &Session,
) -> Result<f64, String> {
    session.predict(cm, fit, kernel, env, device)
}

fn env1(k: &str, v: i64) -> BTreeMap<String, i64> {
    [(k.to_string(), v)].into_iter().collect()
}

// ----------------------------------------------------------------------
// Figures 1 & 2 — the §2 illustrative example on the "GTX Titan X".
// ----------------------------------------------------------------------
fn fig1_fig2(
    madd_component: bool,
    session: &Session,
) -> Result<ExperimentReport, String> {
    let cache = session.cache();
    let (id, title) = if madd_component {
        ("fig2", "madd-component model for tiled matmul (§2.2, Figure 2)")
    } else {
        ("fig1", "single-term model calibrated on matmul itself (Figure 1)")
    };
    let mut rep = ExperimentReport::new(id, title);
    let device = crate::gpusim::device_by_id("gtx_titan_x").unwrap();
    let model = crate::model::Model::new(
        "f_cl_wall_time_gtx_titan_x",
        "p_f32madd * f_op_float32_madd",
    )?;

    // Measurement set: the computation itself (fig1) or the peak-madd
    // microbenchmarks (fig2), exactly the paper's two filter-tag sets.
    let tags: Vec<&str> = if madd_component {
        vec![
            "flops_madd_pattern",
            "dtype:float32",
            "lsize_0:16",
            "lsize_1:16",
            "nelements:524288,786432,1048576,1310720",
            "m:1024,1152,1280,1408",
        ]
    } else {
        vec![
            "matmul_sq",
            "dtype:float32",
            "prefetch:True",
            "lsize_0:16",
            "lsize_1:16",
            "groups_fit:True",
            "n:2048,2560,3072,3584",
        ]
    };
    let m_knls = KernelCollection::all().generate_kernels(&tags)?;
    rep.line(format!("measurement kernels: {}", m_knls.len()));
    let mut data = gather_features_by_ids_cached(
        model.input_features(),
        &m_knls,
        &device,
        cache,
    )?;
    data.scale_features_by_output()?;
    let fit = crate::calibrate::fit_model(&model, &data, &LmOptions::default())?;
    rep.line(format!(
        "p_f32madd = {:.4e} s/madd (residual {:.3e})",
        fit.param("p_f32madd").unwrap(),
        fit.residual
    ));

    let test = build_matmul(crate::ir::DType::F32, true, 16)?.freeze();
    rep.line(format!("{:>6} {:>12} {:>12} {:>8}", "n", "measured", "modeled", "err"));
    for n in [1024i64, 1536, 2048, 2560, 3072, 3584] {
        let env = env1("n", n);
        let measured = measure_with_cache(&device, &test, &env, cache)?.time_s;
        let predicted = eval_with_kernel_cached(
            &model,
            &fit,
            &test,
            &env,
            device.sub_group_size,
            cache,
        )?;
        rep.predictions.push(Prediction {
            device: device.id.into(),
            variant: "matmul_pf".into(),
            sizes: env,
            measured,
            predicted,
            target: "time".into(),
        });
        rep.line(format!(
            "{n:>6} {:>12} {:>12} {:>7.1}%",
            fmt_time(measured),
            fmt_time(predicted),
            100.0 * (predicted - measured).abs() / measured
        ));
    }
    let g = rep.overall_geomean();
    rep.summary.insert("geomean_rel_err".into(), g);
    if madd_component {
        // Figure 2's point: the madd component alone explains only a
        // minority share of the runtime of this gmem-bound kernel.
        let share = rep
            .predictions
            .iter()
            .map(|p| p.predicted / p.measured)
            .sum::<f64>()
            / rep.predictions.len() as f64;
        rep.summary.insert("madd_component_share".into(), share);
    }
    Ok(rep)
}

// ----------------------------------------------------------------------
// Figure 4 — the differentiable step approximation.
// ----------------------------------------------------------------------
fn fig4() -> Result<ExperimentReport, String> {
    let mut rep = ExperimentReport::new(
        "fig4",
        "step function s(x) vs smooth s^(x) with p_edge = 10 (Figure 4)",
    );
    rep.line(format!("{:>6} {:>10} {:>10}", "x", "s(x)", "s^(x)"));
    for i in 0..=10 {
        let x = -1.0 + 0.2 * i as f64;
        let s = if x >= 0.0 { 1.0 } else { 0.0 };
        let s_hat = ((10.0 * x).tanh() + 1.0) / 2.0;
        rep.line(format!("{x:>6.2} {s:>10.1} {s_hat:>10.5}"));
    }
    rep.summary.insert("p_edge".into(), 10.0);
    Ok(rep)
}

// ----------------------------------------------------------------------
// Figure 5 — overlap of local and global memory transactions.
// ----------------------------------------------------------------------

/// Fig. 5's inline cost model: launch overheads, the two tagged global
/// streams, and the local traffic whose hiding is under study.
fn fig5_cost_model(device_id: &str) -> CostModel {
    CostModel::new(device_id, true)
        .term("launch_kernel", "f_sync_kernel_launch", CostGroup::Overhead)
        .term("launch_group", "f_thread_groups", CostGroup::Overhead)
        .term("gin", "f_mem_access_tag:patLD", CostGroup::Gmem)
        .term("gout", "f_mem_access_tag:outST", CostGroup::Gmem)
        .term("f32lmem", "f_mem_access_local_float32", CostGroup::OnChip)
}

/// The local-work sweep of Fig. 5, as a measurement-set filter group.
fn fig5_measurement_sets() -> Vec<Vec<String>> {
    let ms = [0i64, 1, 2, 4, 8, 12, 16, 24, 32, 48, 64];
    vec![vec![
        "overlap_ratio".into(),
        "dtype:float32".into(),
        "nelements:4194304".into(),
        format!(
            "m:{}",
            ms.iter()
                .map(|m| m.to_string())
                .collect::<Vec<_>>()
                .join(",")
        ),
    ]]
}

/// Artifact identity of one device's fig5 overlap fit.  Public so the
/// store's GC reachability set
/// ([`crate::session::reachable_fit_fingerprints`]) covers the
/// experiment harnesses, not just the CLI cases.
pub fn fig5_fit_key(device: &DeviceProfile) -> FitKey {
    fit_key_parts(
        "fig5_overlap",
        device,
        true,
        &fig5_cost_model(device.id),
        &fig5_measurement_sets(),
        Target::Time,
    )
}

fn fig5(aot: Option<&Artifacts>, session: &Session) -> Result<ExperimentReport, String> {
    let cache = session.cache();
    let mut rep = ExperimentReport::new(
        "fig5",
        "modeling overlap of local/global memory transactions (Figure 5)",
    );
    let devices = fleet();

    // Phase 1 (parallel over devices): generate the sweep, and measure
    // it only for devices whose fit is not already in the artifact
    // store — a warm store turns the whole fleet calibration into a
    // disk load.
    let mut gathered = parallel_map(&devices, |device| {
        let cm = fig5_cost_model(device.id);
        let knls = expsets::generate_measurement_kernels(&fig5_measurement_sets())?;
        let key = fig5_fit_key(device);
        let data = if session.stored_fit(&key).is_some() {
            None
        } else {
            let mut data = gather_features_by_ids_cached(
                cm.feature_columns(),
                &knls,
                device,
                cache,
            )?;
            data.scale_features_by_output()?;
            Some(data)
        };
        Ok((cm, knls, key, data))
    })?;

    // Phase 2 (sequential): fits stay on this thread (AOT path); each
    // device's fit loads from the store when fresh, else is fitted and
    // persisted for the next fleet run.
    let mut fits = Vec::with_capacity(devices.len());
    for (device, (cm, knls, key, data)) in devices.iter().zip(gathered.iter_mut()) {
        let fit = match session.stored_fit(key) {
            Some(fit) => fit,
            None => {
                if data.is_none() {
                    // Raced by a concurrent GC between phases: fall
                    // back to a sequential gather.
                    let mut d = gather_features_by_ids_cached(
                        cm.feature_columns(),
                        knls,
                        device,
                        cache,
                    )?;
                    d.scale_features_by_output()?;
                    *data = Some(d);
                }
                let d = data.as_ref().unwrap();
                let fit = match aot {
                    Some(a) => fit_cost_model_aot(a, cm, d, &LmOptions::default())?,
                    None => fit_cost_model_native(cm, d, &LmOptions::default())?,
                };
                session.persist_fit(key, &fit)?;
                fit
            }
        };
        fits.push(fit);
    }

    // Phase 3 (parallel over devices): predict the sweep back (the
    // paper fits and displays the same data) and find the hiding
    // crossover.
    struct Fig5Part {
        line: String,
        summary: (String, f64),
        preds: Vec<Prediction>,
    }
    let jobs: Vec<(usize, &DeviceProfile)> = devices.iter().enumerate().collect();
    let parts = parallel_map(&jobs, |&(i, device)| {
        let (cm, knls, _, _) = &gathered[i];
        let fit = &fits[i];
        let mut t0 = 0.0;
        let mut hidden_up_to = 0i64;
        let mut errs = Vec::new();
        let mut preds = Vec::new();
        for gk in knls {
            let m = gk.env.get("m").copied().unwrap_or(0);
            let measured =
                measure_with_cache(device, &gk.kernel, &gk.env, cache)?.time_s;
            let predicted = predict(cm, fit, &gk.kernel, &gk.env, device, session)?;
            if m == 0 {
                t0 = measured;
            }
            if t0 > 0.0 && measured < 1.20 * t0 {
                hidden_up_to = hidden_up_to.max(m);
            }
            errs.push((predicted - measured).abs() / measured);
            preds.push(Prediction {
                device: device.id.into(),
                variant: format!("m={m}"),
                sizes: gk.env.clone(),
                measured,
                predicted,
                target: "time".into(),
            });
        }
        Ok(Fig5Part {
            line: format!(
                "{:<14} geomean err {:>5.1}%  local accesses hidden up to m ~ {}",
                device.id,
                100.0 * geomean(&errs),
                hidden_up_to
            ),
            summary: (format!("hidden_m_{}", device.id), hidden_up_to as f64),
            preds,
        })
    })?;
    for part in parts {
        rep.predictions.extend(part.preds);
        rep.line(part.line);
        let (k, v) = part.summary;
        rep.summary.insert(k, v);
    }
    rep.summary
        .insert("geomean_rel_err".into(), rep.overall_geomean());
    Ok(rep)
}

// ----------------------------------------------------------------------
// Figure 6 — measurement-kernel sets per model.
// ----------------------------------------------------------------------
fn fig6() -> Result<ExperimentReport, String> {
    let mut rep = ExperimentReport::new(
        "fig6",
        "measurement kernels and features per evaluation model (Figure 6)",
    );
    for case in expsets::eval_cases() {
        let cm = (case.model)("<device>", true);
        rep.line(format!("model '{}' ({} features):", case.id, cm.terms.len()));
        for t in &cm.terms {
            rep.line(format!("   [{:?}] {} <- {}", t.group, t.param, t.feature));
        }
        let knls = expsets::generate_measurement_kernels(&(case.measurement_sets)())?;
        let mut by_gen: BTreeMap<String, usize> = BTreeMap::new();
        for k in &knls {
            *by_gen.entry(k.generator.clone()).or_insert(0) += 1;
        }
        rep.line(format!("   measurement kernels ({} total):", knls.len()));
        for (g, n) in by_gen {
            rep.line(format!("      {g} x{n}"));
        }
    }
    Ok(rep)
}

// ----------------------------------------------------------------------
// Table 1 — the two global load patterns of the prefetching matmul.
// ----------------------------------------------------------------------
fn table1(session: &Session) -> Result<ExperimentReport, String> {
    let cache = session.cache();
    let mut rep = ExperimentReport::new(
        "table1",
        "global load patterns in tiled matmul with prefetching (Table 1)",
    );
    // The §6.1.1 microbenchmark device (its sub-group size also sets
    // the symbolic counting granularity below).
    let device = crate::gpusim::device_by_id("gtx_titan_x").unwrap();
    let k = build_matmul(crate::ir::DType::F32, true, 16)?.freeze();
    let st = cache.get_or_gather(&k, device.sub_group_size)?;
    let e: BTreeMap<String, i128> = [("n".to_string(), 2048i128)].into_iter().collect();
    rep.line(format!(
        "{:>6} {:>8} {:>16} {:>18} {:>12}",
        "array", "ratio", "local strides", "global strides", "loop stride"
    ));
    for (arr, tag) in [("a", "mm_pf_a"), ("b", "mm_pf_b")] {
        let m = st
            .mem_matching(|m| m.tag.as_deref() == Some(tag))
            .next()
            .ok_or_else(|| format!("no access tagged {tag}"))?;
        let ls: Vec<String> = (0..2).map(|i| m.lstrides[i].to_string()).collect();
        let gs: Vec<String> = (0..2).map(|i| m.gstrides[i].to_string()).collect();
        let loop_stride = m
            .loop_strides
            .iter()
            .rev()
            .find(|(_, s)| !s.is_zero())
            .map(|(_, s)| s.to_string())
            .unwrap_or_else(|| "0".into());
        let afr_sym = format!("n/16 = {}", m.afr(&e));
        rep.line(format!(
            "{arr:>6} {:>8} {:>16} {:>18} {:>12}",
            afr_sym,
            format!("{{0:{}, 1:{}}}", ls[0], ls[1]),
            format!("{{0:{}, 1:{}}}", gs[0], gs[1]),
            loop_stride
        ));
        rep.summary
            .insert(format!("afr_{arr}_n2048"), m.afr(&e));
    }
    // The §6.1.1 observation: the isolated b-pattern microbenchmark is
    // several times costlier per load than the a pattern.  The sizes
    // are independent measurements; sweep them on scoped threads (the
    // two pattern kernels are size-invariant, so the cache reduces this
    // to two symbolic passes plus cheap per-size evaluation).
    let mk = |variant: &str, n: i64| -> Result<f64, String> {
        let knls = KernelCollection::all().generate_kernels(&[
            "gmem_from_matmul",
            &format!("variant:{variant}"),
            &format!("n:{n}"),
        ])?;
        measure_with_cache(&device, &knls[0].kernel, &knls[0].env, cache)
            .map(|s| s.time_s)
    };
    let ns = [2048i64, 2560, 3072, 3584];
    let times = parallel_map(&ns, |&n| Ok((mk("pf_a", n)?, mk("pf_b", n)?)))?;
    let mut ratios = Vec::new();
    for (n, (ta, tb)) in ns.iter().zip(times) {
        ratios.push(tb / ta);
        rep.line(format!(
            "isolated pattern cost (n={n}): a={}, b={}  (b/a = {:.2})",
            fmt_time(ta),
            fmt_time(tb),
            tb / ta
        ));
    }
    let mean_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;
    rep.summary.insert("b_over_a_cost_ratio".into(), mean_ratio);
    Ok(rep)
}

// ----------------------------------------------------------------------
// Table 2 — the device fleet.
// ----------------------------------------------------------------------
fn table2() -> Result<ExperimentReport, String> {
    let mut rep = ExperimentReport::new("table2", "platforms used for evaluation (Table 2)");
    for d in fleet() {
        rep.line(format!("{:<28} | {}", d.name, d.opencl_info));
        rep.line(format!(
            "{:<28} |   peak {:.1} TFLOP/s f32, {:.0} GB/s, {} CUs, max WG {}",
            "",
            d.peak_flops() / 1e12,
            d.dram_gbps,
            d.sm_count,
            d.max_wg_size
        ));
    }
    Ok(rep)
}

// ----------------------------------------------------------------------
// Table 3 — matmul model parameters on the Titan V.
// ----------------------------------------------------------------------
fn table3(aot: Option<&Artifacts>, session: &Session) -> Result<ExperimentReport, String> {
    let mut rep = ExperimentReport::new(
        "table3",
        "matmul model parameter values on the Titan V (Table 3)",
    );
    let device = crate::gpusim::device_by_id("titan_v").unwrap();
    let case = &expsets::eval_cases()[0];
    let cal = session.calibrate_case(case, &device, true, aot)?;
    let (cm, fit) = (cal.cm, cal.fit);

    // Modeled cost granularity + implied throughput per feature.
    let app = build_matmul(crate::ir::DType::F32, true, 16)?.freeze();
    let app_stats = session.cache().get_or_gather(&app, device.sub_group_size)?;
    rep.line(format!(
        "{:<42} {:>12} {:>5} {:>14}",
        "feature", "param (s)", "MCG", "rate"
    ));
    for (term, value) in cm.terms.iter().zip(&fit.params) {
        let spec = FeatureSpec::parse(&term.feature)?;
        let (mcg, rate) = granularity_and_rate(&spec, &app_stats, *value);
        rep.line(format!(
            "{:<42} {:>12.3e} {:>5} {:>14}",
            term.feature, value, mcg, rate
        ));
        rep.summary.insert(term.param.clone(), *value);
    }
    let p_edge = fit.params[fit.params.len() - 1];
    rep.line(format!("{:<42} {:>12.3e} {:>5}", "(p_edge)", p_edge, "N/A"));
    rep.summary.insert("p_edge".into(), p_edge);
    rep.line(format!(
        "device peak: {:.1e} FLOP/s, {:.1e} B/s",
        device.peak_flops(),
        device.peak_bw()
    ));
    rep.summary
        .insert("residual".into(), fit.residual);
    Ok(rep)
}

/// Table 3's MCG column and implied-throughput column.
fn granularity_and_rate(
    spec: &FeatureSpec,
    app_stats: &stats::KernelStats,
    p: f64,
) -> (&'static str, String) {
    let rate = |x: f64| -> String {
        if p <= 0.0 {
            return "-".into();
        }
        format!("{:.2e}", x / p)
    };
    match spec {
        FeatureSpec::Op { op, .. } => {
            // Sub-group granularity; madd = 2 FLOPs across 32 lanes.
            let flops = if op == "madd" { 64.0 } else { 32.0 };
            ("SG", format!("{} op/s", rate(flops)))
        }
        FeatureSpec::MemAccess(f) if f.scope == Some(crate::ir::MemScope::Local) => {
            ("SG", format!("{} B/s", rate(32.0 * 4.0)))
        }
        FeatureSpec::MemAccess(f) => {
            // Tagged global features: look up the matching access's
            // counting granularity in the application kernel.
            let gran = f
                .tag
                .as_ref()
                .and_then(|t| {
                    app_stats
                        .mem
                        .iter()
                        .find(|m| m.tag.as_deref() == Some(t.as_str()))
                        .map(|m| m.granularity)
                })
                .unwrap_or(stats::Granularity::WorkItem);
            match gran {
                stats::Granularity::WorkItem => ("WI", format!("{} B/s", rate(4.0))),
                stats::Granularity::SubGroup => {
                    ("SG", format!("{} B/s", rate(32.0 * 4.0)))
                }
            }
        }
        FeatureSpec::MemTransactions { .. } => {
            // One transaction moves one (default) cache line.
            ("SG", format!("{} B/s", rate(128.0)))
        }
        FeatureSpec::BankConflictFactor => {
            ("SG", format!("{} acc/s", rate(1.0)))
        }
        FeatureSpec::SyncBarrierPerWg => ("WG", "-".into()),
        FeatureSpec::ThreadGroups => ("WG", "-".into()),
        FeatureSpec::SyncKernelLaunch => ("K", "-".into()),
        FeatureSpec::WallTime { .. } => ("-", "-".into()),
    }
}

// ----------------------------------------------------------------------
// Table 4 — held-out-device error per calibration target (extension).
// ----------------------------------------------------------------------

/// Cross-machine generalization, one row per (case, target, held-out
/// device): calibrate each evaluation case's model on every fleet
/// device *except* one — per response variable (time, energy, average
/// power) — and predict the held-out machine's measurements with it.
/// The paper's per-device calibration answers "how well does the model
/// explain the machine it was fitted on"; this table answers the
/// harder cross-machine question for each target, which is where the
/// accuracy/scope balance actually bites.  Predictions run on the
/// session's compiled evaluation plans (the same hot path the CLI's
/// `predict` uses).
fn table4(
    aot: Option<&Artifacts>,
    session: &Session,
) -> Result<ExperimentReport, String> {
    let mut rep = ExperimentReport::new(
        "table4",
        "held-out-device error by case and calibration target (cross-machine extension)",
    );
    let devices = fleet();
    for case in &expsets::eval_cases() {
        // fdiff is fitted with the linear form throughout (§8.5); the
        // other two cases use the overlap form.
        let nonlinear = case.id != "fdiff";
        let points = expsets::eval_points(case.id)?;
        rep.line(format!("case {} ({}):", case.id, points.label));

        // Phase 1 (parallel over devices): one gathering per (device,
        // target).  The targets of one device share its measurement
        // sweep and symbolic passes through the session cache — a
        // simulated launch yields every response variable at once.
        let gathered: Vec<Vec<FeatureData>> = parallel_map(&devices, |device| {
            Target::ALL
                .iter()
                .map(|&t| session.gather_case_data_for(case, device, t))
                .collect::<Result<Vec<_>, String>>()
        })?;

        // Phase 2 (sequential; the AOT client stays on this thread):
        // per (target, held-out device), fit the pooled data of the
        // other devices and predict the held-out machine back.
        for (ti, target) in Target::ALL.into_iter().enumerate() {
            rep.line(format!(" target {} ({}):", target.name(), target.unit()));
            let mut t_errs = Vec::new();
            for (di, held_out) in devices.iter().enumerate() {
                if points.kernel.work_group_size() > held_out.max_wg_size {
                    rep.line(format!(
                        "   {:<14} SKIP (work-group too large)",
                        held_out.id
                    ));
                    continue;
                }
                // Pool every *other* device's calibration rows — the
                // fit never sees the held-out machine.
                let mut pool = FeatureData {
                    feature_ids: gathered[0][ti].feature_ids.clone(),
                    scaled: true,
                    target,
                    ..Default::default()
                };
                for (dj, per_target) in gathered.iter().enumerate() {
                    if dj == di {
                        continue;
                    }
                    let d = &per_target[ti];
                    if d.feature_ids != pool.feature_ids {
                        return Err(format!(
                            "feature columns diverge across the fleet: {:?} vs {:?}",
                            pool.feature_ids, d.feature_ids
                        ));
                    }
                    pool.rows.extend(d.rows.iter().cloned());
                    pool.outputs.extend(d.outputs.iter().cloned());
                    pool.labels.extend(d.labels.iter().cloned());
                }
                let cm = (case.model)(held_out.id, nonlinear);
                let opts = LmOptions::default();
                let fit = match aot {
                    Some(a) => fit_cost_model_aot(a, &cm, &pool, &opts)?,
                    None => fit_cost_model_native(&cm, &pool, &opts)?,
                };
                let mut errs = Vec::new();
                let mut mid = (0.0, 0.0);
                for (ei, env) in points.envs.iter().enumerate() {
                    let sample = session.measure(held_out, &points.kernel, env)?;
                    let measured = target.of(&sample);
                    let predicted = session
                        .predict_compiled(&cm, &fit, &points.kernel, env, held_out)?;
                    if ei == 1 {
                        mid = (measured, predicted);
                    }
                    errs.push((predicted - measured).abs() / measured);
                    rep.predictions.push(Prediction {
                        device: held_out.id.into(),
                        variant: points.label.clone(),
                        sizes: env.clone(),
                        measured,
                        predicted,
                        target: target.name().into(),
                    });
                }
                let g = geomean(&errs);
                t_errs.extend(errs);
                rep.line(format!(
                    "   {:<14} geomean err {:>5.1}%   (mid size: measured {}, predicted {})",
                    held_out.id,
                    100.0 * g,
                    fmt_target(target, mid.0),
                    fmt_target(target, mid.1),
                ));
                rep.summary.insert(
                    format!("err_{}_{}_{}", case.id, target.name(), held_out.id),
                    g,
                );
            }
            rep.summary.insert(
                format!("geomean_rel_err_{}_{}", case.id, target.name()),
                geomean(&t_errs),
            );
        }
    }
    rep.summary
        .insert("geomean_rel_err".into(), rep.overall_geomean());
    Ok(rep)
}

// ----------------------------------------------------------------------
// Figures 7, 8, 9 — the three accuracy evaluations.
// ----------------------------------------------------------------------

struct VariantSpec {
    label: String,
    kernel: FrozenKernel,
    envs: Vec<BTreeMap<String, i64>>,
}

/// The paper's §8.1 on-chip-cost-hiding analysis, automated: strip the
/// kernel's on-chip work (work removal keeping every global access),
/// measure the memory-only variant, estimate the removed on-chip cost
/// from the calibrated per-feature parameters, and compare their sum
/// with the full kernel's time.  If a substantial fraction of the
/// on-chip cost is hidden, the nonlinear overlap model (Eq. 8) is the
/// right choice; otherwise the linear model (Eq. 7).
fn onchip_cost_is_hidden(
    cm_lin: &CostModel,
    fit_lin: &FitResult,
    kernel: &FrozenKernel,
    env: &BTreeMap<String, i64>,
    device: &DeviceProfile,
    session: &Session,
) -> Result<bool, String> {
    let cache = session.cache();
    let t_total = measure_with_cache(device, kernel, env, cache)?.time_s;
    let rm = crate::transform::remove_work(
        kernel,
        &crate::transform::remove_work::RemoveSpec::default(),
    )?
    .freeze();
    let t_gmem_only = measure_with_cache(device, &rm, env, cache)?.time_s;
    let st = cache.get_or_gather(kernel, device.sub_group_size)?;
    let envi: BTreeMap<String, i128> =
        env.iter().map(|(k, v)| (k.clone(), *v as i128)).collect();
    let mut onchip_est = 0.0;
    for (term, value) in cm_lin.terms.iter().zip(&fit_lin.params) {
        if term.group == CostGroup::OnChip {
            let spec = FeatureSpec::parse(&term.feature)?;
            onchip_est += spec.eval(&st, &envi)? * value;
        }
    }
    // If on-chip work is negligible the models agree; call it linear.
    if onchip_est < 0.10 * t_total {
        return Ok(false);
    }
    let hidden_fraction = (t_gmem_only + onchip_est - t_total) / onchip_est;
    Ok(hidden_fraction > 0.5)
}

fn accuracy_experiment(
    id: &str,
    title: &str,
    case_idx: usize,
    variants: Vec<VariantSpec>,
    aot: Option<&Artifacts>,
    session: &Session,
) -> Result<ExperimentReport, String> {
    let mut rep = ExperimentReport::new(id, title);
    let cases = expsets::eval_cases();
    let case = &cases[case_idx];
    let devices = fleet();

    // Phase 1 (parallel over devices): one measurement-gathering pass
    // per device serves both model forms.  Devices sharing a sub-group
    // size also share the session cache's symbolic entries — and a
    // device whose fleet fits are already in the artifact store skips
    // its gathering (and the measurement sweep behind it) entirely.
    let mut datas: Vec<Option<FeatureData>> = parallel_map(&devices, |device| {
        if session.has_stored_fits(case, device) {
            Ok(None)
        } else {
            session.gather_case_data(case, device).map(Some)
        }
    })?;

    // Phase 2 (sequential): both fits per device on this thread (the
    // AOT client is not assumed thread-safe), loaded from the store
    // when fresh and persisted for the next fleet run when not.
    let mut fits = Vec::with_capacity(devices.len());
    for (device, data) in devices.iter().zip(datas.iter_mut()) {
        let nl = session.fit_case_persistent(case, device, data, true, aot)?;
        let lin = session.fit_case_persistent(case, device, data, false, aot)?;
        fits.push(((nl.cm, nl.fit), (lin.cm, lin.fit)));
    }

    // Phase 3 (parallel over devices): model-form selection and the
    // prediction sweeps.
    struct DevPart {
        lines: Vec<String>,
        preds: Vec<Prediction>,
        summary: Vec<(String, f64)>,
    }
    let jobs: Vec<_> = devices.iter().zip(&fits).collect();
    let variants = &variants;
    let parts = parallel_map(&jobs, |job| {
        let &(device, fits2) = job;
        let ((cm_nl, fit_nl), (cm_lin, fit_lin)) = fits2;
        let mut part = DevPart {
            lines: Vec::new(),
            preds: Vec::new(),
            summary: Vec::new(),
        };
        let mut dev_errs = Vec::new();
        for v in variants {
            if v.kernel.work_group_size() > device.max_wg_size {
                part.lines.push(format!(
                    "{:<14} {:<14} SKIP (work-group too large)",
                    device.id, v.label
                ));
                continue;
            }
            // §8.1 model-form selection via the automated work-removal
            // overlap analysis at a representative size.
            let probe = &v.envs[v.envs.len() / 2];
            let nonlinear =
                onchip_cost_is_hidden(cm_lin, fit_lin, &v.kernel, probe, device, session)?;
            let linear = !nonlinear;
            let (cm, fit) = if linear {
                (cm_lin, fit_lin)
            } else {
                (cm_nl, fit_nl)
            };
            let mut v_errs = Vec::new();
            for env in &v.envs {
                let measured =
                    measure_with_cache(device, &v.kernel, env, session.cache())?
                        .time_s;
                let predicted = predict(cm, fit, &v.kernel, env, device, session)?;
                v_errs.push((predicted - measured).abs() / measured);
                part.preds.push(Prediction {
                    device: device.id.into(),
                    variant: v.label.clone(),
                    sizes: env.clone(),
                    measured,
                    predicted,
                    target: "time".into(),
                });
            }
            let g = geomean(&v_errs);
            dev_errs.extend(v_errs);
            part.lines.push(format!(
                "{:<14} {:<14}{} geomean err {:>5.1}%",
                device.id,
                v.label,
                if linear { " (L)" } else { "    " },
                100.0 * g
            ));
            part.summary
                .push((format!("err_{}_{}", device.id, v.label), g));
        }
        part.summary
            .push((format!("err_{}", device.id), geomean(&dev_errs)));
        Ok(part)
    })?;
    for part in parts {
        rep.lines.extend(part.lines);
        rep.predictions.extend(part.preds);
        for (k, v) in part.summary {
            rep.summary.insert(k, v);
        }
    }
    let overall = rep.overall_geomean();
    rep.line(format!("overall geomean rel err: {:.1}%", 100.0 * overall));
    rep.summary.insert("geomean_rel_err".into(), overall);

    // Ranking fidelity (the paper's primary criterion): at every
    // (device, size), does the model rank the fastest variant first?
    let mut rank_ok = 0usize;
    let mut rank_total = 0usize;
    for device in fleet() {
        let mut by_size: BTreeMap<String, Vec<&Prediction>> = BTreeMap::new();
        for p in rep.predictions.iter().filter(|p| p.device == device.id) {
            by_size
                .entry(format!("{:?}", p.sizes))
                .or_default()
                .push(p);
        }
        for (_, preds) in by_size {
            if preds.len() < 2 {
                continue;
            }
            let best_measured = preds
                .iter()
                .min_by(|a, b| a.measured.total_cmp(&b.measured))
                .unwrap();
            let best_predicted = preds
                .iter()
                .min_by(|a, b| a.predicted.total_cmp(&b.predicted))
                .unwrap();
            rank_total += 1;
            if best_measured.variant == best_predicted.variant {
                rank_ok += 1;
            }
        }
    }
    if rank_total > 0 {
        rep.line(format!(
            "fastest-variant identification: {rank_ok}/{rank_total}"
        ));
        rep.summary
            .insert("rank_accuracy".into(), rank_ok as f64 / rank_total as f64);
    }
    Ok(rep)
}

fn fig7(aot: Option<&Artifacts>, session: &Session) -> Result<ExperimentReport, String> {
    let ns = [1024i64, 1536, 2048, 2560, 3072, 3584];
    let envs: Vec<_> = ns.iter().map(|&n| env1("n", n)).collect();
    let variants = vec![
        VariantSpec {
            label: "prefetch".into(),
            kernel: build_matmul(crate::ir::DType::F32, true, 16)?.freeze(),
            envs: envs.clone(),
        },
        VariantSpec {
            label: "no_prefetch".into(),
            kernel: build_matmul(crate::ir::DType::F32, false, 16)?.freeze(),
            envs,
        },
    ];
    accuracy_experiment(
        "fig7",
        "matrix multiplication model accuracy (Figure 7)",
        0,
        variants,
        aot,
        session,
    )
}

fn fig8(aot: Option<&Artifacts>, session: &Session) -> Result<ExperimentReport, String> {
    let nels = [65536i64, 131072, 262144];
    let envs: Vec<_> = nels
        .iter()
        .map(|&nel| {
            let mut e = env1("nelements", nel);
            e.insert("nmatrices".into(), 3);
            e
        })
        .collect();
    // Model form (linear vs overlap) is chosen per (device, variant)
    // by the automated §8.1 analysis inside accuracy_experiment — the
    // paper found, e.g., that the u-prefetch variant hides nothing on
    // the Titan V, K40c and C2070.
    let mut variants = Vec::new();
    for v in [
        DgVariant::Plain,
        DgVariant::UPrefetch,
        DgVariant::MPrefetch,
        DgVariant::MPrefetchT,
    ] {
        variants.push(VariantSpec {
            label: v.label().into(),
            kernel: build_dg(v, 64, 16)?.freeze(),
            envs: envs.clone(),
        });
    }
    accuracy_experiment(
        "fig8",
        "DG differentiation model accuracy (Figure 8)",
        1,
        variants,
        aot,
        session,
    )
}

fn fig9(aot: Option<&Artifacts>, session: &Session) -> Result<ExperimentReport, String> {
    let ns = [2016i64, 4032, 6048, 8064];
    let envs: Vec<_> = ns.iter().map(|&n| env1("n", n)).collect();
    let variants = vec![
        VariantSpec {
            label: "16x16".into(),
            kernel: build_fdiff(16)?.freeze(),
            envs: envs.clone(),
        },
        VariantSpec {
            label: "18x18".into(),
            kernel: build_fdiff(18)?.freeze(),
            envs,
        },
    ];
    accuracy_experiment(
        "fig9",
        "finite difference model accuracy (Figure 9; linear model)",
        2,
        variants,
        aot,
        session,
    )
}

// ----------------------------------------------------------------------
// Access — the access-pattern-aware model form (ISSUE 10).
// ----------------------------------------------------------------------

/// The calibration sets of the `access` experiment: the matmul sets
/// plus add-flops and the stencil sweep (the model spans both cases),
/// plus strided `gmem_pattern` kernels so the transaction feature sees
/// uncoalesced traffic during calibration, not just at prediction time.
fn access_measurement_sets() -> Vec<Vec<String>> {
    let mut sets = expsets::matmul_measurement_sets();
    sets.push(vec![
        "flops_add_pattern".into(),
        "dtype:float32".into(),
        "nelements:1048576".into(),
        "m:1024,1152,1280,1408".into(),
    ]);
    sets.push(vec![
        "gmem_from_fdiff".into(),
        "lsize:16,18".into(),
        "n:2016,4032,6048,8064".into(),
    ]);
    sets.push(vec![
        "gmem_pattern".into(),
        "dtype:float32".into(),
        "lid_stride_0:2,4".into(),
        "lid_stride_1:16".into(),
        "n_arrays:1".into(),
        "nelements:4194304".into(),
    ]);
    sets
}

/// Fit [`expsets::access_model`] — a single per-transaction global term
/// (`f_mem_transactions`) plus a bank-conflict excess term instead of
/// one tagged term per distinct pattern — and show the trade on the
/// matmul and stencil variants: fewer parameters, one shared rate.
fn access_experiment(
    aot: Option<&Artifacts>,
    session: &Session,
) -> Result<ExperimentReport, String> {
    let cache = session.cache();
    let mut rep = ExperimentReport::new(
        "access",
        "access-pattern-aware model (f_mem_transactions / \
         f_bank_conflict_factor) on the matmul and stencil variants",
    );
    let m_knls =
        expsets::generate_measurement_kernels(&access_measurement_sets())?;
    rep.line(format!("measurement kernels: {}", m_knls.len()));

    let ns = [1024i64, 2048, 3072];
    let fns = [2016i64, 4032, 6048];
    let variants = vec![
        VariantSpec {
            label: "matmul_pf".into(),
            kernel: build_matmul(crate::ir::DType::F32, true, 16)?.freeze(),
            envs: ns.iter().map(|&n| env1("n", n)).collect(),
        },
        VariantSpec {
            label: "matmul_nopf".into(),
            kernel: build_matmul(crate::ir::DType::F32, false, 16)?.freeze(),
            envs: ns.iter().map(|&n| env1("n", n)).collect(),
        },
        VariantSpec {
            label: "fdiff_16".into(),
            kernel: build_fdiff(16)?.freeze(),
            envs: fns.iter().map(|&n| env1("n", n)).collect(),
        },
        VariantSpec {
            label: "fdiff_18".into(),
            kernel: build_fdiff(18)?.freeze(),
            envs: fns.iter().map(|&n| env1("n", n)).collect(),
        },
    ];

    // One NVIDIA part and the GCN3 part: the feature values are
    // device-independent, the fitted rates are not.
    for dev_id in ["titan_v", "amd_r9_fury"] {
        let device = crate::gpusim::device_by_id(dev_id).unwrap();
        let cm = expsets::access_model(device.id, true);
        let mut data = gather_features_by_ids_cached(
            cm.feature_columns(),
            &m_knls,
            &device,
            cache,
        )?;
        data.scale_features_by_output()?;
        let fit = match aot {
            Some(a) => fit_cost_model_aot(a, &cm, &data, &LmOptions::default())?,
            None => fit_cost_model_native(&cm, &data, &LmOptions::default())?,
        };
        rep.summary
            .insert(format!("residual_{dev_id}"), fit.residual);
        for v in &variants {
            if v.kernel.work_group_size() > device.max_wg_size {
                rep.line(format!(
                    "{:<14} {:<14} SKIP (work-group too large)",
                    device.id, v.label
                ));
                continue;
            }
            let mut v_errs = Vec::new();
            for env in &v.envs {
                let measured =
                    measure_with_cache(&device, &v.kernel, env, cache)?.time_s;
                let predicted =
                    predict(&cm, &fit, &v.kernel, env, &device, session)?;
                v_errs.push((predicted - measured).abs() / measured);
                rep.predictions.push(Prediction {
                    device: device.id.into(),
                    variant: v.label.clone(),
                    sizes: env.clone(),
                    measured,
                    predicted,
                    target: "time".into(),
                });
            }
            let g = geomean(&v_errs);
            rep.line(format!(
                "{:<14} {:<14} geomean err {:>5.1}%",
                device.id,
                v.label,
                100.0 * g
            ));
            rep.summary
                .insert(format!("err_{}_{}", device.id, v.label), g);
        }
    }
    let overall = rep.overall_geomean();
    rep.line(format!("overall geomean rel err: {:.1}%", 100.0 * overall));
    rep.summary.insert("geomean_rel_err".into(), overall);
    Ok(rep)
}

fn all_experiments(
    aot: Option<&Artifacts>,
    session: &Session,
) -> Result<ExperimentReport, String> {
    let mut rep = ExperimentReport::new(
        "all",
        "overall accuracy across all three computations (paper §10: ~6.4%)",
    );
    let mut all_errs = Vec::new();
    for id in ["fig7", "fig8", "fig9"] {
        let sub = dispatch_experiment(id, aot, session)?;
        let g = sub.overall_geomean();
        rep.line(format!("{id}: geomean rel err {:.1}%", 100.0 * g));
        all_errs.extend(sub.predictions.iter().map(Prediction::rel_err));
        rep.predictions.extend(sub.predictions);
        for (k, v) in sub.summary {
            rep.summary.insert(format!("{id}.{k}"), v);
        }
    }
    let overall = geomean(&all_errs);
    rep.line(format!(
        "OVERALL geomean rel err: {:.1}% (paper: 6.4%)",
        100.0 * overall
    ));
    rep.summary.insert("geomean_rel_err".into(), overall);
    // The cross-machine extension rides along (all cases × targets),
    // but stays out of the OVERALL geomean: that number reproduces the
    // paper's §10 per-device evaluation, and held-out-device errors
    // answer a different (harder) question.
    let sub = dispatch_experiment("table4", aot, session)?;
    if let Some(&g) = sub.summary.get("geomean_rel_err") {
        rep.line(format!(
            "table4 (cross-machine, excluded from OVERALL): geomean rel err {:.1}%",
            100.0 * g
        ));
    }
    for (k, v) in sub.summary {
        rep.summary.insert(format!("table4.{k}"), v);
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::{
        fit_model, gather_features_by_ids, FeatureData,
    };
    use crate::gpusim::device_by_id;
    use crate::stats::StatsCache;

    /// The silent empty-fit bug: a device that can launch none of the
    /// measurement kernels must yield a descriptive error, not a
    /// zero-row "fit".
    #[test]
    fn all_skipped_kernels_error_instead_of_empty_fit() {
        let amd = device_by_id("amd_r9_fury").unwrap();
        // 18x18 work-groups (324 work-items) exceed the Fury's limit.
        let knls = KernelCollection::all()
            .generate_kernels(&["gmem_from_fdiff", "lsize:18", "n:2016"])
            .unwrap();
        assert!(!knls.is_empty());
        let err = gather_features_by_ids(
            vec!["f_thread_groups".into()],
            &knls,
            &amd,
        )
        .unwrap_err();
        assert!(err.contains("skipped"), "{err}");
        assert!(err.contains("amd_r9_fury"), "{err}");
    }

    /// Tentpole invariant: cached gathering produces FeatureData
    /// identical to the seed's fresh per-row symbolic passes, across a
    /// whole measurement-kernel collection.
    #[test]
    fn cached_feature_data_matches_fresh_across_collection() {
        let dev = device_by_id("titan_v").unwrap();
        let case = &expsets::eval_cases()[0];
        let kernels =
            expsets::generate_measurement_kernels(&(case.measurement_sets)()).unwrap();
        let ids = (case.model)(dev.id, true).feature_columns();
        // Fresh path: one full symbolic pass per feature row plus one
        // per measurement, exactly what the seed did.
        let specs: Vec<FeatureSpec> = ids
            .iter()
            .map(|id| FeatureSpec::parse(id).unwrap())
            .collect();
        let mut fresh = FeatureData {
            feature_ids: ids.clone(),
            ..Default::default()
        };
        for gk in &kernels {
            let st = crate::stats::gather(&gk.kernel, dev.sub_group_size).unwrap();
            let env: BTreeMap<String, i128> = gk
                .env
                .iter()
                .map(|(k, v)| (k.clone(), *v as i128))
                .collect();
            fresh
                .rows
                .push(specs.iter().map(|s| s.eval(&st, &env).unwrap()).collect());
            fresh.outputs.push(
                crate::gpusim::measure(&dev, &gk.kernel, &gk.env)
                    .unwrap()
                    .time_s,
            );
        }
        let cache = StatsCache::new();
        let cached =
            gather_features_by_ids_cached(ids, &kernels, &dev, &cache).unwrap();
        assert_eq!(fresh.rows, cached.rows);
        assert_eq!(fresh.outputs, cached.outputs);
        assert!(cache.hits() > 0, "measurement must reuse gathered stats");
    }

    /// Acceptance criterion: within one run, the symbolic pass executes
    /// at most once per distinct (kernel, sub-group size).
    #[test]
    fn fig7_style_gathering_counts_each_distinct_kernel_once() {
        let dev = device_by_id("titan_v").unwrap();
        let case = &expsets::eval_cases()[0];
        let kernels =
            expsets::generate_measurement_kernels(&(case.measurement_sets)()).unwrap();
        let distinct: std::collections::HashSet<u128> = kernels
            .iter()
            .map(|gk| gk.kernel.fingerprint())
            .collect();
        let session = Session::new();
        let data = session.gather_case_data(case, &dev).unwrap();
        assert_eq!(data.len(), kernels.len());
        assert_eq!(session.cache().misses(), distinct.len() as u64);
        // A second full gathering is served entirely from the cache.
        let misses_before = session.cache().misses();
        let again = session.gather_case_data(case, &dev).unwrap();
        assert_eq!(session.cache().misses(), misses_before);
        assert_eq!(data.rows, again.rows);
        assert_eq!(data.outputs, again.outputs);
    }

    /// Concurrency smoke test: two devices calibrated in parallel with
    /// a shared cache reproduce the sequential fits bit-for-bit.
    #[test]
    fn parallel_two_device_calibration_matches_sequential() {
        let model = crate::model::Model::new(
            "f_cl_wall_time_titan_v",
            "p_f32madd * f_op_float32_madd + p_launch * f_sync_kernel_launch",
        )
        .unwrap();
        let kernels = KernelCollection::all()
            .generate_kernels(&[
                "flops_madd_pattern",
                "dtype:float32",
                "nelements:524288,1048576",
                "m:1024,1408",
            ])
            .unwrap();
        let devices = [
            device_by_id("titan_v").unwrap(),
            device_by_id("amd_r9_fury").unwrap(),
        ];
        let sequential: Vec<FitResult> = devices
            .iter()
            .map(|d| {
                let mut data =
                    gather_features_by_ids(model.input_features(), &kernels, d)
                        .unwrap();
                data.scale_features_by_output().unwrap();
                fit_model(&model, &data, &LmOptions::default()).unwrap()
            })
            .collect();
        let cache = StatsCache::new();
        let parallel: Vec<FitResult> = std::thread::scope(|s| {
            let handles: Vec<_> = devices
                .iter()
                .map(|d| {
                    let model = &model;
                    let kernels = &kernels;
                    let cache = &cache;
                    s.spawn(move || {
                        let mut data = gather_features_by_ids_cached(
                            model.input_features(),
                            kernels,
                            d,
                            cache,
                        )
                        .unwrap();
                        data.scale_features_by_output().unwrap();
                        fit_model(model, &data, &LmOptions::default()).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (seq, par) in sequential.iter().zip(&parallel) {
            assert_eq!(seq.params, par.params);
            assert_eq!(seq.residual, par.residual);
            assert_eq!(seq.iterations, par.iterations);
        }
        // The two sub-group sizes (warp 32, wavefront 64) are distinct
        // cache keys; within each, every structurally distinct kernel
        // was gathered once (the madd microbenchmark reuses one kernel
        // across its problem sizes).
        let distinct: std::collections::HashSet<u128> = kernels
            .iter()
            .map(|gk| gk.kernel.fingerprint())
            .collect();
        assert_eq!(cache.misses(), 2 * distinct.len() as u64);
    }
}
