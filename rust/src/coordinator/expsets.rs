//! Evaluation models and measurement-kernel sets (the content of the
//! paper's Figure 6).
//!
//! Each evaluation case couples a cost model in the builtin
//! three-component family with the UiPiCK filter-tag sets that generate
//! its calibration microbenchmarks.  Every feature appearing in a
//! measurement kernel also appears in the model (the grey lines of
//! Fig. 6), so no microbenchmark carries unmodeled cost.

use crate::model::{CostGroup, CostModel};
use crate::uipick::{GeneratedKernel, KernelCollection};

/// Overhead terms shared by all three evaluation models.
fn with_overhead(cm: CostModel) -> CostModel {
    cm.term("launch_kernel", "f_sync_kernel_launch", CostGroup::Overhead)
        .term("launch_group", "f_thread_groups", CostGroup::Overhead)
        .term(
            "barrier",
            "f_sync_local_barrier_per_wg",
            CostGroup::Overhead,
        )
}

/// Common microbenchmark tag-sets (flops / lmem / barrier / launch /
/// generic store patterns).
fn common_sets(flops: &[&'static str]) -> Vec<Vec<String>> {
    let mut sets: Vec<Vec<String>> = Vec::new();
    for f in flops {
        sets.push(vec![
            (*f).to_string(),
            "dtype:float32".into(),
            "nelements:1048576".into(),
            "m:1024,1152,1280,1408".into(),
        ]);
    }
    sets.push(vec![
        "lmem_move".into(),
        "stride:1,16".into(),
        "nelements:524288".into(),
        "m:256,512,1024,2048".into(),
    ]);
    sets.push(vec![
        "barrier_pattern".into(),
        "nelements:262144".into(),
        "m:64,128,256,512".into(),
    ]);
    sets.push(vec!["empty_kernel".into()]);
    sets.push(vec![
        "gmem_pattern".into(),
        "dtype:float32".into(),
        "lid_stride_0:1".into(),
        "lid_stride_1:16".into(),
        "n_arrays:1".into(),
        "nelements:4194304,8388608".into(),
    ]);
    // The §7.4 overlap-revealing kernel (Fig. 6a includes it): pins
    // down the step switch between global and on-chip cost.
    sets.push(vec![
        "overlap_ratio".into(),
        "dtype:float32".into(),
        "nelements:4194304".into(),
        "m:0,2,8,24,64".into(),
    ]);
    sets
}

/// One evaluation case (§8.3-8.5).
#[derive(Clone, Copy)]
pub struct EvalCase {
    pub id: &'static str,
    /// Cost-model terms (device-independent; the output feature binds
    /// the device).
    pub model: fn(device: &str, nonlinear: bool) -> CostModel,
    /// Measurement-set filter-tag groups.
    pub measurement_sets: fn() -> Vec<Vec<String>>,
}

/// §8.3 matrix multiplication model: five distinct global patterns
/// (four tagged per-variant loads + the generic stride-1 store).
pub fn matmul_model(device: &str, nonlinear: bool) -> CostModel {
    with_overhead(CostModel::new(device, nonlinear))
        .term("mm_pf_a", "f_mem_access_tag:mm_pf_a", CostGroup::Gmem)
        .term("mm_pf_b", "f_mem_access_tag:mm_pf_b", CostGroup::Gmem)
        .term("mm_nopf_a", "f_mem_access_tag:mm_nopf_a", CostGroup::Gmem)
        .term("mm_nopf_b", "f_mem_access_tag:mm_nopf_b", CostGroup::Gmem)
        .term("pat", "f_mem_access_tag:patLD", CostGroup::Gmem)
        .term(
            "gst",
            "f_mem_access_global_float32_store",
            CostGroup::Gmem,
        )
        .term("f32madd", "f_op_float32_madd", CostGroup::OnChip)
        .term("f32lmem", "f_mem_access_local_float32", CostGroup::OnChip)
}

pub fn matmul_measurement_sets() -> Vec<Vec<String>> {
    let mut sets = common_sets(&["flops_madd_pattern"]);
    sets.push(vec![
        "gmem_from_matmul".into(),
        "variant:pf_a,pf_b,nopf_a,nopf_b".into(),
        // Cover both cache regimes of the evaluation sweep.
        "n:1024,1536,2048,2560,3072,3584".into(),
    ]);
    sets
}

/// §8.4 DG model: per-variant u/diff_mat/res patterns (the 11+ distinct
/// patterns of Fig. 6b).
pub fn dg_model(device: &str, nonlinear: bool) -> CostModel {
    let mut cm = with_overhead(CostModel::new(device, nonlinear));
    for tag in [
        "dg_u_direct",
        "dg_u_fetch",
        "dg_u_direct_t",
        "dg_dm_direct",
        "dg_dm_direct_mloop",
        "dg_dm_fetch",
        "dg_res",
        "dg_res_t",
    ] {
        cm = cm.term(tag, &format!("f_mem_access_tag:{tag}"), CostGroup::Gmem);
    }
    cm.term("pat", "f_mem_access_tag:patLD", CostGroup::Gmem)
        .term(
            "gst",
            "f_mem_access_global_float32_store",
            CostGroup::Gmem,
        )
        .term("f32madd", "f_op_float32_madd", CostGroup::OnChip)
        // Stride-characterized local features (§6.1.1 notes local
        // accesses may carry the same pattern characteristics as
        // global ones; the u-prefetch variant's tile reads are
        // lid(0)-strided and bank-conflicted, so one undifferentiated
        // local feature cannot model all four variants).
        .term(
            "f32lmem",
            "f_mem_access_local_float32_lstrides:{0:<2}",
            CostGroup::OnChip,
        )
        .term(
            "f32lmem_strided",
            "f_mem_access_local_float32_lstrides:{0:>1}",
            CostGroup::OnChip,
        )
}

pub fn dg_measurement_sets() -> Vec<Vec<String>> {
    let mut sets = common_sets(&["flops_madd_pattern"]);
    sets.push(vec![
        "gmem_from_dg".into(),
        "pattern:plain_u,plain_dm,upf_u,upf_dm,mpf_dm,mpf_u,t_u,res_store,t_res_store"
            .into(),
        "nelements:131072,262144".into(),
    ]);
    sets
}

/// §8.5 finite-difference model (fitted with the *linear* form).
pub fn fdiff_model(device: &str, nonlinear: bool) -> CostModel {
    with_overhead(CostModel::new(device, nonlinear))
        .term("fd16_u", "f_mem_access_tag:fd16_u", CostGroup::Gmem)
        .term("fd18_u", "f_mem_access_tag:fd18_u", CostGroup::Gmem)
        .term("pat", "f_mem_access_tag:patLD", CostGroup::Gmem)
        .term(
            "gst",
            "f_mem_access_global_float32_store",
            CostGroup::Gmem,
        )
        .term("f32add", "f_op_float32_add", CostGroup::OnChip)
        .term("f32madd", "f_op_float32_madd", CostGroup::OnChip)
        .term("f32lmem", "f_mem_access_local_float32", CostGroup::OnChip)
}

pub fn fdiff_measurement_sets() -> Vec<Vec<String>> {
    let mut sets = common_sets(&["flops_madd_pattern", "flops_add_pattern"]);
    sets.push(vec![
        "gmem_from_fdiff".into(),
        "lsize:16,18".into(),
        "n:2016,4032,6048,8064".into(),
    ]);
    sets
}

/// The access-pattern-aware model form (ISSUE 10): instead of one
/// tagged term per distinct global pattern, a single
/// `f_mem_transactions` term charges every global access its
/// coalescing-model transaction count, and `f_bank_conflict_factor`
/// charges local accesses their excess bank serialization.  Scope
/// (§5): fewer parameters than the per-tag models, at the cost of
/// assuming one per-transaction rate — the `access` experiment shows
/// where that trade lands on the matmul/stencil variants.
///
/// Not part of [`eval_cases`] (the Fig. 6 set is fixed at three); the
/// `access` experiment fits it directly.
pub fn access_model(device: &str, nonlinear: bool) -> CostModel {
    with_overhead(CostModel::new(device, nonlinear))
        .term("gtxn", "f_mem_transactions", CostGroup::Gmem)
        .term("f32add", "f_op_float32_add", CostGroup::OnChip)
        .term("f32madd", "f_op_float32_madd", CostGroup::OnChip)
        .term("f32lmem", "f_mem_access_local_float32", CostGroup::OnChip)
        .term("bankx", "f_bank_conflict_factor", CostGroup::OnChip)
}

/// The three evaluation cases.
pub fn eval_cases() -> Vec<EvalCase> {
    vec![
        EvalCase {
            id: "matmul",
            model: matmul_model,
            measurement_sets: matmul_measurement_sets,
        },
        EvalCase {
            id: "dg",
            model: dg_model,
            measurement_sets: dg_measurement_sets,
        },
        EvalCase {
            id: "fdiff",
            model: fdiff_model,
            measurement_sets: fdiff_measurement_sets,
        },
    ]
}

/// Look one evaluation case up by id (the CLI's `<case>` argument).
pub fn eval_case(id: &str) -> Option<EvalCase> {
    eval_cases().into_iter().find(|c| c.id == id)
}

/// One representative held-out application kernel per evaluation case,
/// with the problem sizes it is predicted at.  Shared by the table-4
/// cross-machine harness and the compiled-vs-exact equivalence suite,
/// so both exercise the same (kernel, env) points; the remaining
/// variants per case are covered by figs. 7-9.
pub struct EvalPoints {
    /// Variant label used in prediction records.
    pub label: String,
    pub kernel: crate::ir::FrozenKernel,
    pub envs: Vec<std::collections::BTreeMap<String, i64>>,
}

/// Build the evaluation points of one case.
pub fn eval_points(case_id: &str) -> Result<EvalPoints, String> {
    use crate::uipick::apps::{build_dg, build_fdiff, build_matmul, DgVariant};
    fn env1(k: &str, v: i64) -> std::collections::BTreeMap<String, i64> {
        let mut e = std::collections::BTreeMap::new();
        e.insert(k.to_string(), v);
        e
    }
    match case_id {
        "matmul" => Ok(EvalPoints {
            label: "matmul_pf".into(),
            kernel: build_matmul(crate::ir::DType::F32, true, 16)?.freeze(),
            envs: [1024i64, 2048, 3072]
                .iter()
                .map(|&n| env1("n", n))
                .collect(),
        }),
        "dg" => Ok(EvalPoints {
            label: "dg_plain".into(),
            kernel: build_dg(DgVariant::Plain, 64, 16)?.freeze(),
            envs: [65536i64, 131072, 262144]
                .iter()
                .map(|&nel| {
                    let mut e = env1("nelements", nel);
                    e.insert("nmatrices".into(), 3);
                    e
                })
                .collect(),
        }),
        "fdiff" => Ok(EvalPoints {
            label: "fdiff_16".into(),
            kernel: build_fdiff(16)?.freeze(),
            envs: [2016i64, 4032, 6048]
                .iter()
                .map(|&n| env1("n", n))
                .collect(),
        }),
        other => Err(format!("no evaluation points for case '{other}'")),
    }
}

/// Generate the union of a case's measurement kernels.
pub fn generate_measurement_kernels(
    sets: &[Vec<String>],
) -> Result<Vec<GeneratedKernel>, String> {
    let collection = KernelCollection::all();
    let mut out = Vec::new();
    for tags in sets {
        let refs: Vec<&str> = tags.iter().map(|s| s.as_str()).collect();
        let knls = collection.generate_kernels(&refs)?;
        if knls.is_empty() {
            return Err(format!("measurement set {tags:?} produced no kernels"));
        }
        out.extend(knls);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_cases_generate_nonempty_sets_within_artifact_capacity() {
        for case in eval_cases() {
            let sets = (case.measurement_sets)();
            let knls = generate_measurement_kernels(&sets)
                .unwrap_or_else(|e| panic!("{}: {e}", case.id));
            assert!(
                (20..=128).contains(&knls.len()),
                "{}: {} measurement kernels",
                case.id,
                knls.len()
            );
            let cm = (case.model)("titan_v", true);
            assert!(
                cm.terms.len() <= 24,
                "{}: {} features exceeds artifact J",
                case.id,
                cm.terms.len()
            );
        }
    }

    #[test]
    fn models_cover_every_feature_in_their_measurement_kernels() {
        // The Fig. 6 closure property: every classifiable cost source
        // in a measurement kernel is matched by some model feature.
        use crate::features::FeatureSpec;
        for case in eval_cases() {
            let cm = (case.model)("titan_v", true);
            let specs: Vec<FeatureSpec> = cm
                .feature_columns()
                .iter()
                .map(|f| FeatureSpec::parse(f).unwrap())
                .collect();
            let knls =
                generate_measurement_kernels(&(case.measurement_sets)()).unwrap();
            // Closure must hold at every sub-group size in the fleet
            // (warp 32 on the NVIDIA parts, wavefront 64 on GCN3).
            let mut sgs: Vec<u64> = crate::gpusim::fleet()
                .iter()
                .map(|d| d.sub_group_size)
                .collect();
            sgs.sort_unstable();
            sgs.dedup();
            for gk in &knls {
                for &sg in &sgs {
                    let st = crate::stats::gather(&gk.kernel, sg).unwrap();
                    let env: std::collections::BTreeMap<String, i128> = gk
                        .env
                        .iter()
                        .map(|(k, v)| (k.clone(), *v as i128))
                        .collect();
                    // Global accesses must be covered.
                    for m in st.mem.iter().filter(|m| {
                        m.scope == crate::ir::MemScope::Global
                    }) {
                        let covered = specs.iter().any(|s| match s {
                            FeatureSpec::MemAccess(f) => f.matches(m, &env),
                            _ => false,
                        });
                        assert!(
                            covered,
                            "{}: kernel {} (sg {sg}) access {:?}/{:?} uncovered",
                            case.id, gk.kernel.name, m.array, m.tag
                        );
                    }
                }
            }
        }
    }
}
