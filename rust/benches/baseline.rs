//! Perf baselines for the three hot paths the artifact store exists to
//! keep fast — checked in as `BENCH_*.json` so a perf regression shows
//! up as a diff, not a memory:
//!
//! * **counting pass** — one full symbolic statistics gather, the cost
//!   the stats cache amortizes;
//! * **warm predict** — a store-warm calibration plus one prediction,
//!   the paper's "near-zero cost" claim (zero LM iterations, zero
//!   counting passes);
//! * **store open** — `Session::with_store` against a populated store,
//!   the per-process price of the journaled index.
//!
//! Writes `BENCH_counting_pass.json`, `BENCH_warm_predict.json` and
//! `BENCH_store_open.json` into `$PERFLEX_BENCH_DIR` (default: the
//! working directory).

use perflex::bench_harness::{bench_recorded, write_baseline};
use perflex::coordinator::expsets;
use perflex::gpusim::device_by_id;
use perflex::session::Session;
use perflex::uipick::apps::build_matmul;

fn main() {
    let out_dir = std::env::var("PERFLEX_BENCH_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));

    let dev = device_by_id("titan_v").unwrap();
    let case = &expsets::eval_cases()[0];
    let kernel = build_matmul(perflex::ir::DType::F32, true, 16)
        .unwrap()
        .freeze();
    let env: std::collections::BTreeMap<String, i64> =
        [("n".to_string(), 2048i64)].into_iter().collect();

    // 1. The counting pass (uncached by construction: a fresh gather
    // each iteration).
    let counting = bench_recorded("counting pass (matmul_pf, sg=32)", 20, || {
        let _ = perflex::stats::gather(&kernel, 32).unwrap();
    });
    let p = write_baseline(&out_dir, "counting_pass", &[counting]).unwrap();
    println!("baseline written to {}", p.display());

    // Populate a store once (cold calibration), then measure the warm
    // paths against it.
    let store_dir = std::env::temp_dir()
        .join(format!("perflex-bench-baseline-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    {
        let cold = Session::with_store(&store_dir).unwrap();
        let cal = cold.calibrate_case(case, &dev, true, None).unwrap();
        assert!(!cal.from_store);
    }

    // 2. Warm predict: store-backed calibrate (a disk load) plus one
    // prediction — the end-to-end "near-zero cost" path.
    let session = Session::with_store(&store_dir).unwrap();
    let warm = bench_recorded("warm calibrate+predict (matmul, titan_v)", 50, || {
        let cal = session.calibrate_case(case, &dev, true, None).unwrap();
        assert!(cal.from_store);
        let _ = session
            .predict(&cal.cm, &cal.fit, &kernel, &env, &dev)
            .unwrap();
    });
    let p = write_baseline(&out_dir, "warm_predict", &[warm]).unwrap();
    println!("baseline written to {}", p.display());

    // 3. Store open: index snapshot + journal replay for a populated
    // store, paid once per process.
    let open = bench_recorded("Session::with_store (populated store)", 50, || {
        let s = Session::with_store(&store_dir).unwrap();
        assert!(s.store().is_some());
    });
    let p = write_baseline(&out_dir, "store_open", &[open]).unwrap();
    println!("baseline written to {}", p.display());

    let _ = std::fs::remove_dir_all(&store_dir);
}
