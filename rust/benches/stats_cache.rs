//! Benchmark: repeated feature gathering through the memoized
//! [`StatsCache`] vs the seed path (a fresh symbolic pass per use),
//! plus the disk-warm start a persistent artifact store enables.
//!
//! The acceptance bar for the cache subsystem is a >= 2x speedup on
//! repeated gathering; in practice a warm cache turns the polyhedral
//! counting pass into a hash lookup, so the ratio is orders of
//! magnitude.  A calibration-shaped loop (each kernel "used" twice per
//! pass, once for measurement and once for its feature row — exactly
//! the seed's duplication) is reported alongside, plus the hit/miss
//! ledger.  The disk-warm variant simulates a fresh process against a
//! store populated by an earlier run: cold memory, warm disk — the
//! counting pass is replaced by JSON decoding.

use std::sync::Arc;

use perflex::bench_harness::bench;
use perflex::ir::FrozenKernel;
use perflex::session::ArtifactStore;
use perflex::stats::{self, StatsCache};
use perflex::uipick::apps::{build_dg, build_fdiff, build_matmul, DgVariant};

fn workload() -> Vec<FrozenKernel> {
    vec![
        build_matmul(perflex::ir::DType::F32, true, 16).unwrap().freeze(),
        build_matmul(perflex::ir::DType::F32, false, 16).unwrap().freeze(),
        build_dg(DgVariant::MPrefetchT, 64, 16).unwrap().freeze(),
        build_dg(DgVariant::UPrefetch, 64, 16).unwrap().freeze(),
        build_fdiff(16).unwrap().freeze(),
        build_fdiff(18).unwrap().freeze(),
    ]
}

fn main() {
    let kernels = workload();

    // Seed path: every use re-derives the full symbolic bundle, twice
    // per kernel per pass (measure + feature row).
    bench("feature gather x2, fresh (seed path)", 20, || {
        for k in &kernels {
            let _ = stats::gather(k, 32).unwrap();
            let _ = stats::gather(k, 32).unwrap();
        }
    });

    // Cached path: one symbolic pass per distinct kernel for the whole
    // program run, everything after that is a lookup keyed by the
    // frozen fingerprint.
    let cache = StatsCache::new();
    bench("feature gather x2, StatsCache", 20, || {
        for k in &kernels {
            let _ = cache.get_or_gather(k, 32).unwrap();
            let _ = cache.get_or_gather(k, 32).unwrap();
        }
    });
    println!(
        "cache ledger: {} misses (one per distinct kernel), {} hits",
        cache.misses(),
        cache.hits()
    );
    assert_eq!(cache.misses(), kernels.len() as u64);

    // Disk-warm start: a prior run populated the store; each iteration
    // plays a fresh process (empty in-memory cache) that loads every
    // bundle from disk instead of re-counting.
    let dir = std::env::temp_dir().join(format!(
        "perflex-bench-store-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    {
        let seed = StatsCache::with_backing(store.clone());
        for k in &kernels {
            let _ = seed.get_or_gather(k, 32).unwrap();
        }
        assert_eq!(seed.misses(), kernels.len() as u64);
    }
    let mut last_disk_hits = 0;
    bench("feature gather x2, disk-warm StatsCache", 20, || {
        let fresh = StatsCache::with_backing(store.clone());
        for k in &kernels {
            let _ = fresh.get_or_gather(k, 32).unwrap();
            let _ = fresh.get_or_gather(k, 32).unwrap();
        }
        last_disk_hits = fresh.disk_hits();
    });
    println!(
        "disk-warm ledger: {} disk hits per pass, 0 symbolic passes",
        last_disk_hits
    );
    assert_eq!(last_disk_hits, kernels.len() as u64);
    // Every disk hit above was answered through the store index (the
    // artifacts were saved by this process, so the in-memory manifest
    // vouches for them): zero probe/validate parses across all passes.
    let (index_hits, parses) = store.ledger();
    println!("store index: {index_hits} index hits, {parses} full-artifact parses");
    assert_eq!(parses, 0, "index must vouch for every disk-warm load");
    let _ = std::fs::remove_dir_all(&dir);

    // Parallel measurement sweep: the per-kernel loop of
    // gather_features_by_ids_cached on worker threads vs the
    // sequential reference, over a real measurement-kernel collection
    // (matmul case, Titan V).  Cold caches per pass so each iteration
    // pays the full measure + count + bind pipeline; outputs asserted
    // byte-identical.
    let case = &perflex::coordinator::expsets::eval_cases()[0];
    let m_knls = perflex::coordinator::expsets::generate_measurement_kernels(
        &(case.measurement_sets)(),
    )
    .unwrap();
    let dev = perflex::gpusim::device_by_id("titan_v").unwrap();
    let ids = (case.model)(dev.id, true).feature_columns();
    let mut seq_data = None;
    bench("measurement sweep, sequential reference", 5, || {
        seq_data = Some(
            perflex::calibrate::gather_features_by_ids_sequential(
                ids.clone(),
                &m_knls,
                &dev,
                &StatsCache::new(),
            )
            .unwrap(),
        );
    });
    let mut par_data = None;
    bench("measurement sweep, parallel workers", 5, || {
        par_data = Some(
            perflex::calibrate::gather_features_by_ids_cached(
                ids.clone(),
                &m_knls,
                &dev,
                &StatsCache::new(),
            )
            .unwrap(),
        );
    });
    assert_eq!(
        seq_data, par_data,
        "parallel sweep must be byte-identical to sequential"
    );
}
