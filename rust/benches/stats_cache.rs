//! Benchmark: repeated feature gathering through the memoized
//! [`StatsCache`] vs the seed path (a fresh symbolic pass per use).
//!
//! The acceptance bar for the cache subsystem is a >= 2x speedup on
//! repeated gathering; in practice a warm cache turns the polyhedral
//! counting pass into a hash lookup, so the ratio is orders of
//! magnitude.  A calibration-shaped loop (each kernel "used" twice per
//! pass, once for measurement and once for its feature row — exactly
//! the seed's duplication) is reported alongside, plus the hit/miss
//! ledger.

use perflex::bench_harness::bench;
use perflex::ir::Kernel;
use perflex::stats::{self, StatsCache};
use perflex::uipick::apps::{build_dg, build_fdiff, build_matmul, DgVariant};

fn workload() -> Vec<Kernel> {
    vec![
        build_matmul(perflex::ir::DType::F32, true, 16).unwrap(),
        build_matmul(perflex::ir::DType::F32, false, 16).unwrap(),
        build_dg(DgVariant::MPrefetchT, 64, 16).unwrap(),
        build_dg(DgVariant::UPrefetch, 64, 16).unwrap(),
        build_fdiff(16).unwrap(),
        build_fdiff(18).unwrap(),
    ]
}

fn main() {
    let kernels = workload();

    // Seed path: every use re-derives the full symbolic bundle, twice
    // per kernel per pass (measure + feature row).
    bench("feature gather x2, fresh (seed path)", 20, || {
        for k in &kernels {
            let _ = stats::gather(k, 32).unwrap();
            let _ = stats::gather(k, 32).unwrap();
        }
    });

    // Cached path: one symbolic pass per distinct kernel for the whole
    // program run, everything after that is a lookup.
    let cache = StatsCache::new();
    bench("feature gather x2, StatsCache", 20, || {
        for k in &kernels {
            let _ = cache.get_or_gather(k, 32).unwrap();
            let _ = cache.get_or_gather(k, 32).unwrap();
        }
    });
    println!(
        "cache ledger: {} misses (one per distinct kernel), {} hits",
        cache.misses(),
        cache.hits()
    );
    assert_eq!(cache.misses(), kernels.len() as u64);
}
