//! Compiled batched prediction vs the exact per-query evaluator.
//!
//! The exact path re-parses every feature and re-walks the symbolic
//! statistics (rational arithmetic, BTreeMap environments) on every
//! query; the compiled path lowers the fitted model once to a flat f64
//! evaluation plan (`perflex::model::compiled`) and each sweep point is
//! a dense loop over slot-indexed values.  This bench measures both
//! over the same sweep and records the throughput ratio — the PR's
//! acceptance criterion (>= 100x) is asserted here, so any toolchain
//! that can run the bench also enforces it.
//!
//! Writes `BENCH_batched_eval.json` into `$PERFLEX_BENCH_DIR`
//! (default: the working directory) with a `summary` carrying
//! `speedup` and `evals_per_sec`.

use perflex::bench_harness::{bench_recorded, write_baseline_with_summary};
use perflex::coordinator::expsets;
use perflex::gpusim::device_by_id;
use perflex::model::COMPILED_REL_ERR_BOUND;
use perflex::session::Session;
use perflex::uipick::apps::build_matmul;

fn main() {
    let out_dir = std::env::var("PERFLEX_BENCH_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));

    let dev = device_by_id("titan_v").unwrap();
    let case = &expsets::eval_cases()[0];
    let kernel = build_matmul(perflex::ir::DType::F32, true, 16)
        .unwrap()
        .freeze();

    // Populate a store once (cold calibration), then benchmark warm.
    let store_dir = std::env::temp_dir()
        .join(format!("perflex-bench-batched-eval-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    {
        let cold = Session::with_store(&store_dir).unwrap();
        let cal = cold.calibrate_case(case, &dev, true, None).unwrap();
        assert!(!cal.from_store);
    }
    let session = Session::with_store(&store_dir).unwrap();
    let cal = session.calibrate_case(case, &dev, true, None).unwrap();
    assert!(cal.from_store);

    // One sweep: n over 256 consecutive sizes.
    let ns: Vec<i64> = (0..256).map(|i| 1024 + i).collect();
    let base_env: std::collections::BTreeMap<String, i64> =
        std::collections::BTreeMap::new();

    // Correctness spot-check before timing anything: the compiled rows
    // must agree with the exact evaluator within the documented bound
    // (the full sweep is property-tested in tests/compiled_equivalence.rs).
    let rows = session
        .predict_sweep(&cal.cm, &cal.fit, &kernel, &base_env, "n", &ns, &dev)
        .unwrap();
    for (x, compiled) in &rows {
        let env: std::collections::BTreeMap<String, i64> =
            [("n".to_string(), *x)].into_iter().collect();
        let exact = session
            .predict(&cal.cm, &cal.fit, &kernel, &env, &dev)
            .unwrap();
        let denom = exact.abs().max(compiled.abs()).max(f64::MIN_POSITIVE);
        assert!(
            (compiled - exact).abs() / denom <= COMPILED_REL_ERR_BOUND,
            "n={x}: compiled {compiled} vs exact {exact}"
        );
    }

    // 1. The exact per-query path (the pre-compiled-plan baseline):
    // feature parse + symbolic statistics walk per query.
    let exact = bench_recorded("exact per-query predict x256 (matmul, titan_v)", 20, || {
        for &n in &ns {
            let env: std::collections::BTreeMap<String, i64> =
                [("n".to_string(), n)].into_iter().collect();
            let _ = session
                .predict(&cal.cm, &cal.fit, &kernel, &env, &dev)
                .unwrap();
        }
    });

    // 2. The compiled sweep: one plan lookup, then a dense f64 loop.
    let compiled = bench_recorded("compiled sweep x256 (matmul, titan_v)", 200, || {
        let _ = session
            .predict_sweep(&cal.cm, &cal.fit, &kernel, &base_env, "n", &ns, &dev)
            .unwrap();
    });

    // 3. A single compiled query (plan served from the session cache),
    // the CLI's warm `predict` hot path.
    let env2048: std::collections::BTreeMap<String, i64> =
        [("n".to_string(), 2048i64)].into_iter().collect();
    let single = bench_recorded("compiled single predict (matmul, titan_v)", 200, || {
        let _ = session
            .predict_compiled(&cal.cm, &cal.fit, &kernel, &env2048, &dev)
            .unwrap();
    });

    let speedup = exact.mean_ms / compiled.mean_ms;
    let evals_per_sec = ns.len() as f64 / (compiled.mean_ms / 1e3);
    println!(
        "batched speedup: {speedup:.0}x   throughput: {evals_per_sec:.3e} evals/s"
    );
    // The PR's acceptance criterion, enforced wherever the bench runs.
    assert!(
        speedup >= 100.0,
        "compiled batched eval must be >= 100x the exact path, got {speedup:.1}x"
    );

    let p = write_baseline_with_summary(
        &out_dir,
        "batched_eval",
        &[exact, compiled, single],
        &[("speedup", speedup), ("evals_per_sec", evals_per_sec)],
    )
    .unwrap();
    println!("baseline written to {}", p.display());

    let _ = std::fs::remove_dir_all(&store_dir);
}
