//! Benchmark: simulated-GPU measurement throughput (the experiment
//! harnesses call this thousands of times).
use perflex::bench_harness::bench;
use perflex::gpusim::{device_by_id, measure, simulate_time};
use perflex::uipick::apps::build_matmul;

fn main() {
    let knl = build_matmul(perflex::ir::DType::F32, true, 16).unwrap();
    let dev = device_by_id("titan_v").unwrap();
    let env = [("n".to_string(), 2048i64)].into_iter().collect();
    bench("simulate_time(matmul_pf)", 100, || {
        let _ = simulate_time(&dev, &knl, &env).unwrap();
    });
    bench("measure(matmul_pf) [60 trials]", 100, || {
        let _ = measure(&dev, &knl, &env).unwrap();
    });
}
