//! Benchmark + ablation: Levenberg-Marquardt calibration through the
//! native symbolic path vs the AOT JAX/Pallas artifact (EXPERIMENTS.md
//! §Perf records the comparison).
use perflex::bench_harness::bench;
use perflex::calibrate::{FeatureData, LmOptions};
use perflex::model::{CostGroup, CostModel};
use perflex::runtime::{artifacts_available, fit_cost_model_aot, fit_cost_model_native, Artifacts};
use perflex::util::Rng;

fn synthetic(rows: usize, terms: usize) -> (CostModel, FeatureData) {
    let mut cm = CostModel::new("titan_v", true);
    for i in 0..terms {
        let g = match i % 3 {
            0 => CostGroup::Overhead,
            1 => CostGroup::Gmem,
            _ => CostGroup::OnChip,
        };
        cm = cm.term(&format!("t{i}"), &format!("f_mem_access_tag:x{i}"), g);
    }
    let mut rng = Rng::new(9);
    let mut data = FeatureData {
        feature_ids: cm.feature_columns(),
        ..Default::default()
    };
    for _ in 0..rows {
        let f: Vec<f64> = (0..terms).map(|_| rng.uniform_in(0.2, 2.0)).collect();
        let t: f64 = f.iter().enumerate().map(|(i, v)| 0.1 * (i + 1) as f64 * v).sum();
        data.rows.push(f);
        data.outputs.push(t);
        data.labels.push("syn".into());
    }
    data.scale_features_by_output().unwrap();
    (cm, data)
}

fn main() {
    let (cm, data) = synthetic(100, 12);
    let opts = LmOptions::default();
    bench("LM fit, native symbolic backend", 20, || {
        let _ = fit_cost_model_native(&cm, &data, &opts).unwrap();
    });
    if artifacts_available() {
        let artifacts = Artifacts::load().unwrap();
        bench("LM fit, AOT JAX/Pallas backend", 20, || {
            let _ = fit_cost_model_aot(&artifacts, &cm, &data, &opts).unwrap();
        });
    } else {
        println!("bench LM fit, AOT backend: SKIPPED (run `make artifacts`)");
    }
}
