//! Benchmark: symbolic statistics gathering (Algorithm 1+2) and
//! quasi-polynomial re-evaluation — the paper's amortization claim.
use perflex::bench_harness::bench;
use perflex::uipick::apps::{build_dg, build_matmul, DgVariant};

fn main() {
    let mm = build_matmul(perflex::ir::DType::F32, true, 16).unwrap();
    let dg = build_dg(DgVariant::MPrefetchT, 64, 16).unwrap();
    bench("stats::gather(matmul_pf)", 50, || {
        let _ = perflex::stats::gather(&mm, 32).unwrap();
    });
    bench("stats::gather(dg_m_prefetch_t)", 50, || {
        let _ = perflex::stats::gather(&dg, 32).unwrap();
    });
    // Amortized re-evaluation: one gather, many sizes.
    let st = perflex::stats::gather(&mm, 32).unwrap();
    let madd = st.op_count(perflex::ir::DType::F32, "madd");
    bench("QPoly re-eval x1000 sizes", 20, || {
        let mut acc = 0.0;
        for n in 0..1000i128 {
            let e = [("n".to_string(), 1024 + 16 * n)].into_iter().collect();
            acc += madd.eval_f64(&e);
        }
        assert!(acc > 0.0);
    });
    bench("kernel build+transform (matmul_pf)", 50, || {
        let _ = build_matmul(perflex::ir::DType::F32, true, 16).unwrap();
    });
}
