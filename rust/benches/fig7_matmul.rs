//! End-to-end harness benchmark: regenerates the paper's fig7 and
//! reports its headline statistics plus wall time.
use perflex::bench_harness::bench;

fn main() {
    let mut summary = std::collections::BTreeMap::new();
    bench("experiment fig7 (end-to-end)", 3, || {
        let rep = perflex::coordinator::run_experiment("fig7", true).unwrap();
        summary = rep.summary.clone();
    });
    for (k, v) in &summary {
        println!("    fig7.{k} = {v:.6}");
    }
}
