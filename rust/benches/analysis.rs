//! Benchmark: the static kernel verifier (`perflex::analysis`) per
//! kernel family — the gate cost every counted, measured, or autotuned
//! candidate pays before the rest of the pipeline touches it.  Writes
//! `BENCH_analysis.json` into `$PERFLEX_BENCH_DIR` (default: the
//! working directory); the `bench-baselines` CI job tracks it against
//! the checked-in copy.

use perflex::analysis::Analyzer;
use perflex::bench_harness::{bench_recorded, write_baseline_with_summary};
use perflex::ir::DType;
use perflex::uipick::apps::{build_dg, build_fdiff, build_matmul, build_transpose, DgVariant};
use perflex::uipick::micro::build_barrier_pattern;

fn main() {
    let out_dir = std::env::var("PERFLEX_BENCH_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));

    let analyzer = Analyzer::new();
    let families = [
        (
            "verify matmul_pf",
            build_matmul(DType::F32, true, 16).unwrap(),
        ),
        (
            "verify dg_m_prefetch_t",
            build_dg(DgVariant::MPrefetchT, 64, 16).unwrap(),
        ),
        ("verify fdiff_18x18", build_fdiff(18).unwrap()),
        ("verify transpose", build_transpose(16).unwrap()),
        (
            "verify barrier_pattern",
            build_barrier_pattern(DType::F32).unwrap(),
        ),
    ];

    let mut records = Vec::new();
    for (name, knl) in &families {
        records.push(bench_recorded(name, 100, || {
            let diags = analyzer.check(knl);
            assert!(diags.is_empty(), "{name}: {diags:?}");
        }));
    }

    // Throughput summary: how many candidate kernels per second the
    // autotune pruning gate can clear (mean over the family mix).
    let total_mean_ms: f64 = records.iter().map(|r| r.mean_ms).sum();
    let kernels_per_sec = families.len() as f64 * 1e3 / total_mean_ms.max(1e-6);
    let p = write_baseline_with_summary(
        &out_dir,
        "analysis",
        &records,
        &[("kernels_per_sec", kernels_per_sec)],
    )
    .unwrap();
    println!("baseline written to {}", p.display());
}
