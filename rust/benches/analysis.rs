//! Benchmark: the static kernel verifier (`perflex::analysis`) per
//! kernel family — the gate cost every counted, measured, or autotuned
//! candidate pays before the rest of the pipeline touches it.  Writes
//! `BENCH_analysis.json` into `$PERFLEX_BENCH_DIR` (default: the
//! working directory); the `bench-baselines` CI job tracks it against
//! the checked-in copy.

use perflex::analysis::{access, admissible, check_equiv, check_feasibility, Analyzer};
use perflex::bench_harness::{bench_recorded, write_baseline_with_summary};
use perflex::gpusim::{device_by_id, fleet};
use perflex::ir::DType;
use perflex::uipick::apps::{
    build_dg, build_fdiff, build_matmul, build_transpose, fdiff_base, matmul_base, DgVariant,
};
use perflex::uipick::micro::build_barrier_pattern;

fn main() {
    let out_dir = std::env::var("PERFLEX_BENCH_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));

    let analyzer = Analyzer::new();
    // Expected diagnostic codes per family: the transposed store is
    // genuinely uncoalesced (a Warn-severity access-pattern finding);
    // everything else verifies spotless.
    let families = [
        (
            "verify matmul_pf",
            build_matmul(DType::F32, true, 16).unwrap(),
            vec![],
        ),
        (
            "verify dg_m_prefetch_t",
            build_dg(DgVariant::MPrefetchT, 64, 16).unwrap(),
            vec![],
        ),
        ("verify fdiff_18x18", build_fdiff(18).unwrap(), vec![]),
        (
            "verify transpose",
            build_transpose(16).unwrap(),
            vec!["UNCOALESCED_GLOBAL"],
        ),
        (
            "verify barrier_pattern",
            build_barrier_pattern(DType::F32).unwrap(),
            vec![],
        ),
    ];

    let mut records = Vec::new();
    for (name, knl, expected) in &families {
        records.push(bench_recorded(name, 100, || {
            let diags = analyzer.check(knl);
            let codes: Vec<&str> = diags.iter().map(|d| d.code.as_str()).collect();
            assert_eq!(&codes, expected, "{name}: {diags:?}");
        }));
    }

    // Throughput summary: how many candidate kernels per second the
    // hygiene gate can clear (mean over the family mix).  Computed over
    // the verify records only so the figure stays comparable across
    // baselines as further gate stages are benchmarked below.
    let total_mean_ms: f64 = records.iter().map(|r| r.mean_ms).sum();
    let kernels_per_sec = families.len() as f64 * 1e3 / total_mean_ms.max(1e-6);

    // The rest of the pruning predicate: resource feasibility across
    // the whole fleet, transform-chain equivalence, and the combined
    // `admissible` gate on the paper's scope example (the 18x18 tile
    // that AMD's 256-item work-group limit rejects).
    let devices = fleet();
    let fdiff18 = build_fdiff(18).unwrap();
    records.push(bench_recorded("feasibility fleet fdiff_18x18", 100, || {
        for d in &devices {
            let f = check_feasibility(&fdiff18, d).unwrap();
            assert_eq!(f.usage.wg_size, 324, "{}", d.id);
        }
    }));

    let mm_base = matmul_base(DType::F32, true);
    let mm_cand = build_matmul(DType::F32, true, 16).unwrap();
    records.push(bench_recorded("equiv matmul_pf", 100, || {
        let diags = check_equiv(&mm_base, &mm_cand);
        assert!(diags.is_empty(), "{diags:?}");
    }));

    let amd = device_by_id("amd_r9_fury").unwrap();
    let fd_base = fdiff_base(18);
    records.push(bench_recorded("admissible fdiff_18x18 amd", 100, || {
        assert!(admissible(&fd_base, &fdiff18, &amd).is_err());
    }));

    // The access-pattern pass on its own: the per-candidate report the
    // pruning gate attaches to Ok results, on the worst case (the
    // transposed store's parametric stride needs env sampling) and
    // across the whole fleet's geometries.
    let titan = device_by_id("titan_v").unwrap();
    let transpose = build_transpose(16).unwrap();
    records.push(bench_recorded("access report transpose titan_v", 100, || {
        let rep = access::report(&transpose, &titan).unwrap();
        assert_eq!(rep.penalized().len(), 1, "{rep:?}");
    }));
    records.push(bench_recorded("access report matmul_pf fleet", 100, || {
        for d in &devices {
            let knl = &families[0].1;
            let rep = access::report(knl, d).unwrap();
            assert!(rep.penalized().is_empty(), "{}: {rep:?}", d.id);
        }
    }));

    let p = write_baseline_with_summary(
        &out_dir,
        "analysis",
        &records,
        &[("kernels_per_sec", kernels_per_sec)],
    )
    .unwrap();
    println!("baseline written to {}", p.display());
}
