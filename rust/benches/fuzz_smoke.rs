//! Deterministic randomized no-panic smoke target — the offline crate
//! set has no `cargo-fuzz`/libFuzzer, so this plain bench binary plays
//! that role on two parser/serializer surfaces that take untrusted
//! text:
//!
//! 1. `Assumptions::parse`: mutated clause soup must never panic, and
//!    every accepted string must also be accepted when parsed again
//!    (idempotent acceptance).
//! 2. The `perflex lint --json` document: reports built from
//!    adversarial diagnostic strings (quotes, backslashes, control
//!    characters, non-ASCII) must serialize to JSON that the in-tree
//!    parser round-trips.
//!
//! Iteration count comes from `PERFLEX_FUZZ_ITERS` (default 2000 — the
//! CI short smoke mode); the seed is fixed so failures reproduce.

use perflex::analysis::{report_to_json, DiagCode, Diagnostic, LintEntry};
use perflex::polyhedral::Assumptions;
use perflex::util::json::Json;
use perflex::util::Rng;

fn iters() -> u64 {
    std::env::var("PERFLEX_FUZZ_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000)
}

/// Characters the assumption grammar uses, plus noise it must reject
/// gracefully.
const ASSUME_CHARS: &[char] = &[
    'n', 'm', 'x', '_', '0', '1', '2', '9', ' ', '>', '=', '%', '-', '+', 'a',
    'd', '(', ')', '\t', '\u{e9}',
];

fn mutate(rng: &mut Rng, base: &str) -> String {
    let mut chars: Vec<char> = base.chars().collect();
    for _ in 0..rng.below(4) + 1 {
        let c = ASSUME_CHARS[rng.below(ASSUME_CHARS.len() as u64) as usize];
        match rng.below(3) {
            0 if !chars.is_empty() => {
                let i = rng.below(chars.len() as u64) as usize;
                chars[i] = c;
            }
            1 => {
                let i = rng.below(chars.len() as u64 + 1) as usize;
                chars.insert(i, c);
            }
            _ if !chars.is_empty() => {
                let i = rng.below(chars.len() as u64) as usize;
                chars.remove(i);
            }
            _ => {}
        }
    }
    chars.into_iter().collect()
}

fn fuzz_assumptions(rng: &mut Rng, n: u64) -> (u64, u64) {
    let corpus = [
        "n >= 16 and n % 16 = 0",
        "nelements >= 32768 and nmatrices >= 3",
        "m % 254 = 0",
        "n >= 2",
        "",
    ];
    let (mut ok, mut err) = (0u64, 0u64);
    for i in 0..n {
        let base = corpus[(i % corpus.len() as u64) as usize];
        let text = mutate(rng, base);
        match Assumptions::parse(&text) {
            Ok(_) => {
                ok += 1;
                // Acceptance must be stable under re-parse.
                Assumptions::parse(&text).unwrap_or_else(|e| {
                    panic!("accepted then rejected {text:?}: {e}")
                });
            }
            Err(_) => err += 1,
        }
    }
    (ok, err)
}

/// A hostile string: JSON-escaping landmines plus raw code points.
fn wild_string(rng: &mut Rng) -> String {
    let mut s = String::new();
    for _ in 0..rng.below(12) {
        s.push(match rng.below(8) {
            0 => '"',
            1 => '\\',
            2 => '\n',
            3 => '\u{1}',
            4 => '\u{e9}',
            5 => '\u{1f600}',
            6 => '/',
            _ => char::from(b'a' + (rng.below(26) as u8)),
        });
    }
    s
}

fn fuzz_lint_json(rng: &mut Rng, n: u64) {
    let all = DiagCode::all();
    for _ in 0..n {
        let mut entries = Vec::new();
        for _ in 0..rng.below(3) + 1 {
            let diags: Vec<Diagnostic> = (0..rng.below(4))
                .map(|_| Diagnostic {
                    code: all[rng.below(all.len() as u64) as usize],
                    kernel: wild_string(rng),
                    stmt: if rng.below(2) == 0 {
                        None
                    } else {
                        Some(wild_string(rng))
                    },
                    object: if rng.below(2) == 0 {
                        None
                    } else {
                        Some(wild_string(rng))
                    },
                    message: wild_string(rng),
                })
                .collect();
            entries.push(LintEntry {
                kernel: wild_string(rng),
                generator: wild_string(rng),
                diags,
                feasibility: Vec::new(),
            });
        }
        let text = report_to_json(&entries).to_string();
        let parsed = Json::parse(&text)
            .unwrap_or_else(|e| panic!("emitted unparseable JSON: {e}\n{text}"));
        // The document head must survive the trip.
        assert_eq!(
            parsed.get("version").and_then(Json::as_i64),
            Some(3),
            "{text}"
        );
    }
}

fn main() {
    let n = iters();
    let mut rng = Rng::new(0x5EED_F00D);
    let (ok, err) = fuzz_assumptions(&mut rng, n);
    // The corpus seeds are valid, so mutation must keep finding both
    // accepted and rejected strings — otherwise the target is dead.
    assert!(ok > 0 && err > 0, "degenerate corpus: ok={ok} err={err}");
    fuzz_lint_json(&mut rng, n);
    println!(
        "fuzz_smoke: {n} assumption mutations ({ok} ok / {err} rejected), \
         {n} lint JSON round-trips — no panics"
    );
}
