//! Quickstart: the paper's Section 2 walk-through, end to end.
//!
//! Builds the tiled matrix-multiplication kernel via Loopy-style
//! transformations, defines the one-term model of Eq. (1), calibrates
//! it two ways (on the computation itself = Figure 1; on the peak-madd
//! microbenchmarks = Figure 2) and prints measured-vs-modeled times.
//!
//! Run: `cargo run --release --example quickstart`

use perflex::calibrate::{eval_with_kernel, fit_model, gather_feature_values, LmOptions};
use perflex::coordinator::report::fmt_time;
use perflex::gpusim::{device_by_id, measure};
use perflex::model::Model;
use perflex::schedule::linearize;
use perflex::uipick::{apps::build_matmul, KernelCollection};

fn main() -> Result<(), String> {
    // 1. Kernel creation and transformation (§2.1): the builder chains
    //    split_iname / tag_inames / assume / add_prefetch.
    let knl = build_matmul(perflex::ir::DType::F32, true, 16)?;
    println!("--- generated schedule (compare §2.1's OpenCL listing) ---");
    print!("{}", linearize(&knl)?.listing(&knl));

    // 2. Define the model of Eq. (1): t(n) ~ p_madd * f_madd(n).
    let model = Model::new(
        "f_cl_wall_time_gtx_titan_x",
        "p_f32madd * f_op_float32_madd",
    )?;
    let device = device_by_id("gtx_titan_x").unwrap();

    // 3. Generate measurement kernels with UiPiCK filter tags (§2.2).
    let m_knls = KernelCollection::all().generate_kernels(&[
        "matmul_sq",
        "dtype:float32",
        "prefetch:True",
        "lsize_0:16",
        "lsize_1:16",
        "groups_fit:True",
        "n:2048,2560,3072,3584",
    ])?;
    println!("\nmeasurement kernels: {}", m_knls.len());

    // 4. Gather feature values and fit (§7.2).
    let mut data = gather_feature_values(&model, &m_knls, &device)?;
    data.scale_features_by_output()?;
    let fit = fit_model(&model, &data, &LmOptions::default())?;
    println!(
        "calibrated p_f32madd = {:.3e} s per sub-group madd",
        fit.param("p_f32madd").unwrap()
    );

    // 5. Predict execution times (Figure 1).
    println!("\n--- Figure 1: app-kernel calibration ---");
    println!("{:>6} {:>12} {:>12} {:>7}", "n", "measured", "modeled", "err");
    for n in [1024i64, 1536, 2048, 2560, 3072, 3584] {
        let env = [("n".to_string(), n)].into_iter().collect();
        let t = measure(&device, &knl, &env)?;
        let p = eval_with_kernel(&model, &fit, &knl, &env, 32)?;
        println!(
            "{n:>6} {:>12} {:>12} {:>6.1}%",
            fmt_time(t),
            fmt_time(p),
            100.0 * (p - t).abs() / t
        );
    }

    // 6. Same model calibrated on the peak-madd microbenchmarks
    //    (Figure 2): now the prediction isolates the madd component.
    let micro = KernelCollection::all().generate_kernels(&[
        "flops_madd_pattern",
        "dtype:float32",
        "nelements:524288,786432,1048576,1310720",
        "m:1024,1152,1280,1408",
    ])?;
    let mut data2 = gather_feature_values(&model, &micro, &device)?;
    data2.scale_features_by_output()?;
    let fit2 = fit_model(&model, &data2, &LmOptions::default())?;
    println!("\n--- Figure 2: madd-component (peak-throughput calibration) ---");
    println!("{:>6} {:>12} {:>14} {:>8}", "n", "measured", "madd component", "share");
    for n in [2048i64, 2560, 3072, 3584] {
        let env = [("n".to_string(), n)].into_iter().collect();
        let t = measure(&device, &knl, &env)?;
        let p = eval_with_kernel(&model, &fit2, &knl, &env, 32)?;
        println!(
            "{n:>6} {:>12} {:>14} {:>7.1}%",
            fmt_time(t),
            fmt_time(p),
            100.0 * p / t
        );
    }
    println!("\n(The gap is the point: this kernel is memory-bound, so madds");
    println!("alone explain only a fraction of its runtime.)");
    Ok(())
}
