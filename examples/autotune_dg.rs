//! Autotuning use case (the paper's §4 motivation): use a calibrated
//! model as a *pruning heuristic* — rank the four DG differentiation
//! variants per device without running them, then verify the ranking
//! against actual execution.
//!
//! Run: `cargo run --release --example autotune_dg`

use perflex::calibrate::eval_with_kernel;
use perflex::coordinator::experiments::calibrate_case;
use perflex::coordinator::expsets;
use perflex::coordinator::report::fmt_time;
use perflex::gpusim::{fleet, measure};
use perflex::uipick::apps::{build_dg, DgVariant};

fn main() -> Result<(), String> {
    let cases = expsets::eval_cases();
    let dg_case = &cases[1];
    let env: std::collections::BTreeMap<String, i64> = [
        ("nelements".to_string(), 131072i64),
        ("nmatrices".to_string(), 3),
    ]
    .into_iter()
    .collect();
    let variants = [
        DgVariant::Plain,
        DgVariant::UPrefetch,
        DgVariant::MPrefetch,
        DgVariant::MPrefetchT,
    ];

    let aot = if perflex::runtime::artifacts_available() {
        Some(perflex::runtime::Artifacts::load()?)
    } else {
        None
    };
    let mut correct = 0;
    let mut total = 0;
    for device in fleet() {
        println!("== {} ==", device.name);
        let (cm, fit) = calibrate_case(dg_case, &device, true, aot.as_ref())?;
        let model = cm.to_model();
        let mut rows = Vec::new();
        for v in variants {
            let knl = build_dg(v, 64, 16)?;
            let predicted = eval_with_kernel(&model, &fit, &knl, &env, 32)?;
            let measured = measure(&device, &knl, &env)?;
            rows.push((v.label(), predicted, measured));
        }
        let mut by_pred = rows.clone();
        by_pred.sort_by(|a, b| a.1.total_cmp(&b.1));
        let mut by_meas = rows.clone();
        by_meas.sort_by(|a, b| a.2.total_cmp(&b.2));
        for (label, p, m) in &rows {
            println!(
                "   {label:<14} predicted {:>10}  measured {:>10}",
                fmt_time(*p),
                fmt_time(*m)
            );
        }
        let pred_best = by_pred[0].0;
        let meas_best = by_meas[0].0;
        total += 1;
        if pred_best == meas_best {
            correct += 1;
        }
        println!(
            "   model picks '{pred_best}', truth is '{meas_best}' -> {}",
            if pred_best == meas_best { "CORRECT" } else { "MISS" }
        );
    }
    println!("\nfastest-variant identification: {correct}/{total} devices");
    if correct < total {
        return Err("model failed to identify the fastest variant somewhere".into());
    }
    Ok(())
}
