//! Custom models + the work-removal transformation (paper §6.1.1 and
//! §7.1.1): reproduce Table 1's observation that the matmul `b`-pattern
//! costs several times more per load than the `a` pattern, by isolating
//! each access with `remove_work` and calibrating a *user-written*
//! Perflex model expression through the general (native) path.
//!
//! Run: `cargo run --release --example custom_model_workremoval`

use perflex::calibrate::{fit_model, gather_feature_values, LmOptions};
use perflex::gpusim::device_by_id;
use perflex::model::Model;
use perflex::schedule::linearize;
use perflex::stats;
use perflex::transform::remove_work::{remove_work, RemoveSpec};
use perflex::uipick::{apps::build_matmul, KernelCollection};

fn main() -> Result<(), String> {
    let knl = build_matmul(perflex::ir::DType::F32, true, 16)?;

    // §7.1.1: strip everything except the b load (remove a and c).
    let spec = RemoveSpec {
        remove_arrays: vec!["c".into()],
        remove_tags: vec!["mm_pf_a".into()],
    };
    let only_b = remove_work(&knl, &spec)?;
    println!("--- work-removed kernel (compare the paper's §7.1.1 listing) ---");
    print!("{}", linearize(&only_b)?.listing(&only_b));

    // Table 1: the two access patterns, from the statistics module.
    let st = stats::gather(&knl, 32)?;
    let e: std::collections::BTreeMap<String, i128> =
        [("n".to_string(), 2048i128)].into_iter().collect();
    println!("\n--- Table 1 (n = 2048) ---");
    for tag in ["mm_pf_a", "mm_pf_b"] {
        let m = st
            .mem_matching(|m| m.tag.as_deref() == Some(tag))
            .next()
            .unwrap();
        println!(
            "{tag}: AFR={} lstrides=({}, {}) gstrides=({}, {})",
            m.afr(&e),
            m.lstrides[0],
            m.lstrides[1],
            m.gstrides[0],
            m.gstrides[1],
        );
    }

    // A custom user model, written as a plain expression string and
    // fitted through the general symbolic-differentiation path: per-tag
    // global costs plus launch overheads.
    let device = device_by_id("gtx_titan_x").unwrap();
    let model = Model::new(
        "f_cl_wall_time_gtx_titan_x",
        "p_launch * f_sync_kernel_launch + \
         p_wg * f_thread_groups + \
         p_a * f_mem_access_tag:mm_pf_a + \
         p_b * f_mem_access_tag:mm_pf_b + \
         p_st * f_mem_access_global_float32_store",
    )?;
    let m_knls = KernelCollection::all().generate_kernels(&[
        "gmem_from_matmul",
        "variant:pf_a,pf_b",
        "n:2048,2560,3072,3584",
    ])?;
    let mut data = gather_feature_values(&model, &m_knls, &device)?;
    data.scale_features_by_output()?;
    let fit = fit_model(&model, &data, &LmOptions::default())?;
    let pa = fit.param("p_a").unwrap();
    let pb = fit.param("p_b").unwrap();
    println!("\ncalibrated per-load costs: a = {pa:.3e} s, b = {pb:.3e} s");
    println!(
        "b/a cost ratio = {:.2} (the paper observed 4-5x on the Titan X)",
        pb / pa
    );
    if pb <= pa {
        return Err("expected the b pattern to cost more per load".into());
    }
    Ok(())
}
