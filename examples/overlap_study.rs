//! Overlap study (paper §7.4 / Figure 5): sweep the ratio of local to
//! global memory traffic and watch which devices hide on-chip cost —
//! then run the fig5 harness, which fits the nonlinear overlap model to
//! the same sweep on all five devices.
//!
//! Run: `cargo run --release --example overlap_study`

use perflex::coordinator::report::fmt_time;
use perflex::coordinator::run_experiment;
use perflex::gpusim::{fleet, measure};
use perflex::uipick::KernelCollection;

fn main() -> Result<(), String> {
    // Raw sweep: time vs m (local load-store pairs per global pair).
    let ms = [0i64, 2, 4, 8, 16, 32, 64];
    println!("{:<14} {}", "device", ms.map(|m| format!("{m:>10}")).join(""));
    for device in fleet() {
        let mut row = format!("{:<14}", device.id);
        for m in ms {
            let knls = KernelCollection::all().generate_kernels(&[
                "overlap_ratio",
                "dtype:float32",
                "nelements:4194304",
                &format!("m:{m}"),
            ])?;
            let t = measure(&device, &knls[0].kernel, &knls[0].env)?;
            row.push_str(&format!("{:>10}", fmt_time(t)));
        }
        println!("{row}");
    }
    println!(
        "\n(Kepler/Fermi rows grow immediately; Volta/Maxwell/GCN3 stay \
         flat until local traffic exceeds the global transactions it \
         hides behind — the paper's Figure 5.)\n"
    );

    // The full Figure 5 reproduction: nonlinear model fit per device.
    let rep = run_experiment("fig5", true)?;
    print!("{}", rep.render());
    Ok(())
}
